//! The Hapi client — the compute-tier half of the system (§5.2, §5.4).
//!
//! Per application it profiles the model (§5.3; the static profile comes
//! from the AOT metadata), chooses the split index once (Algorithm 1),
//! then per training iteration fans out one POST per storage object,
//! reorders the intermediate results into training-batch order
//! (preserving the learning trajectory), executes the leftover frozen
//! units `[split+1, freeze]` at the *training* batch size, and trains the
//! tail with gradient accumulation over micro-batches + one SGD update —
//! numerically a full-batch step (see `python/compile/model.py`).
//!
//! Iterations are double-buffered: iteration `k+1`'s POSTs are in flight
//! while iteration `k` computes, the same overlap the paper's baseline
//! and Hapi both employ.

pub mod dataset;

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::HapiConfig;
use crate::cos::protocol::CosConnection;
use crate::error::{Error, Result};
use crate::netsim::Link;
use crate::profiler::AppProfile;
use crate::runtime::{DeviceKind, DeviceSim, ModelArtifacts, Tensor};
use crate::server::request::{PostRequest, RequestMode};
use crate::split::{choose_split_idx, SplitDecision};

pub use dataset::{DatasetRef, DatasetSpec};

/// Outcome of one epoch.
#[derive(Debug, Clone, Default)]
pub struct EpochStats {
    pub iterations: usize,
    pub loss: Vec<f32>,
    pub accuracy: Vec<f32>,
    /// Wall time blocked on network+COS results (per iteration).
    pub comm: Duration,
    /// Wall time computing locally (per iteration sums).
    pub comp: Duration,
    pub bytes_from_cos: u64,
    pub bytes_to_cos: u64,
}

impl EpochStats {
    pub fn mean_loss(&self) -> f32 {
        if self.loss.is_empty() {
            0.0
        } else {
            self.loss.iter().sum::<f32>() / self.loss.len() as f32
        }
    }

    pub fn final_loss(&self) -> f32 {
        self.loss.last().copied().unwrap_or(0.0)
    }
}

pub struct HapiClient {
    pub app: AppProfile,
    pub split: SplitDecision,
    arts: Arc<ModelArtifacts>,
    cfg: HapiConfig,
    addr: String,
    link: Link,
    device_kind: DeviceKind,
    device: Arc<DeviceSim>,
    tail_params: Mutex<Vec<Tensor>>,
    next_req_id: std::sync::atomic::AtomicU64,
}

impl HapiClient {
    /// The §7 BASELINE: stream raw images with GETs and run the whole
    /// network on the compute tier.  Encoded as split index 0 (no units
    /// pushed down); everything else (pipelining, training, memory
    /// accounting) is shared with the Hapi path, mirroring §6's "users
    /// provide the same training parameters in both cases".
    #[allow(clippy::too_many_arguments)]
    pub fn new_baseline(
        app: AppProfile,
        arts: Arc<ModelArtifacts>,
        cfg: HapiConfig,
        addr: String,
        link: Link,
        device_kind: DeviceKind,
    ) -> HapiClient {
        let split = SplitDecision {
            split_idx: 0,
            out_bytes_per_sample: app.input_bytes(),
            bytes_per_iteration: app.input_bytes() * cfg.train_batch as u64,
            candidates: vec![],
        };
        let device =
            DeviceSim::new("client-dev", device_kind, cfg.client_gpu_mem, 0);
        let tail_params = Mutex::new(arts.initial_tail_params());
        HapiClient {
            app,
            split,
            arts,
            cfg,
            addr,
            link,
            device_kind,
            device,
            tail_params,
            next_req_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// `split_override` forces a split index (the §7.3 static-freeze
    /// competitor); `None` runs Algorithm 1.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        app: AppProfile,
        arts: Arc<ModelArtifacts>,
        cfg: HapiConfig,
        addr: String,
        link: Link,
        device_kind: DeviceKind,
        split_override: Option<usize>,
    ) -> HapiClient {
        let split = match split_override {
            Some(idx) => SplitDecision {
                split_idx: idx,
                out_bytes_per_sample: app.out_bytes(idx),
                bytes_per_iteration: app.out_bytes(idx)
                    * cfg.train_batch as u64,
                candidates: vec![idx],
            },
            None => choose_split_idx(
                &app,
                link.rate(),
                cfg.split_window_secs,
                cfg.train_batch,
            ),
        };
        let device = DeviceSim::new(
            "client-dev",
            device_kind,
            cfg.client_gpu_mem,
            0,
        );
        let tail_params = Mutex::new(arts.initial_tail_params());
        HapiClient {
            app,
            split,
            arts,
            cfg,
            addr,
            link,
            device_kind,
            device,
            tail_params,
            next_req_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    pub fn device(&self) -> &Arc<DeviceSim> {
        &self.device
    }

    fn req_id(&self) -> u64 {
        self.next_req_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    }

    /// Fan out one request per shard of the iteration and reassemble the
    /// results in shard order (the reorder buffer of §5.2).  Hapi mode
    /// (split ≥ 1) POSTs feature-extraction requests; BASELINE (split 0)
    /// GETs the raw image objects.
    fn fetch_features(&self, ds: &DatasetRef, shards: &[usize]) -> Result<Tensor> {
        let mem = self.app.memory();
        let split = self.split.split_idx;
        let slots: Vec<Mutex<Option<Result<Tensor>>>> =
            shards.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for (slot, &shard) in slots.iter().zip(shards) {
                let link = self.link.clone();
                let addr = self.addr.clone();
                let samples = ds
                    .shard_samples
                    .min(ds.num_samples - shard * ds.shard_samples);
                let mut dims = vec![samples];
                dims.extend(&ds.input_shape);
                let key = crate::cos::ObjectKey::shard(&ds.name, shard);
                if split == 0 {
                    // BASELINE: stream the raw object.
                    scope.spawn(move || {
                        let result = (|| -> Result<Tensor> {
                            let mut conn =
                                CosConnection::connect(&addr, link)?;
                            let body = conn.get(&key)?;
                            Tensor::from_raw(
                                crate::runtime::DType::F32,
                                dims,
                                body,
                            )
                        })();
                        *slot.lock().unwrap() = Some(result);
                    });
                    continue;
                }
                let req = PostRequest {
                    id: self.req_id(),
                    model: self.app.model.name.clone(),
                    split_idx: split,
                    object: key,
                    labels_object: String::new(),
                    input_dims: dims,
                    b_max: self.cfg.object_samples.min(samples),
                    mem_data_per_sample: mem.fe_data_bytes_per_sample(split),
                    mem_model_bytes: mem.fe_model_bytes(split),
                    mode: RequestMode::FeatureExtract,
                };
                scope.spawn(move || {
                    let result = (|| -> Result<Tensor> {
                        let mut conn = CosConnection::connect(&addr, link)?;
                        let (header, body) =
                            conn.post(req.to_json(), Vec::new())?;
                        let dims =
                            header.get("out_dims")?.as_usize_vec()?;
                        Tensor::from_raw(
                            crate::runtime::DType::F32,
                            dims,
                            body,
                        )
                    })();
                    *slot.lock().unwrap() = Some(result);
                });
            }
        });
        // Reorder: shard order == training-batch order, regardless of
        // POST completion order.
        let mut parts = Vec::with_capacity(shards.len());
        for slot in slots {
            parts.push(slot.into_inner().unwrap().unwrap()?);
        }
        Tensor::concat_batch(&parts)
    }

    /// Compute phase for one iteration: leftover frozen units at the
    /// training batch size, then grad accumulation + one SGD update.
    fn compute_iteration(&self, feats: Tensor, labels: &[i32]) -> Result<(f32, f32)> {
        let split = self.split.split_idx;
        let freeze = self.app.freeze_idx();
        let mem = self.app.memory();
        let _lease = self
            .device
            .admit(mem.client_bytes(split, feats.dims[0]))?;

        let feats = if split < freeze {
            self.arts.forward_segment(
                &feats,
                split + 1,
                freeze,
                self.device_kind,
                None,
            )?
        } else {
            feats
        };

        let mb = self.arts.micro_batch();
        let n = feats.dims[0];
        debug_assert_eq!(n, labels.len());
        let mut tail = self.tail_params.lock().unwrap();
        let mut grad_sums: Option<Vec<Tensor>> = None;
        let mut loss_sum = 0.0f32;
        let mut correct_sum = 0.0f32;
        let mut off = 0;
        while off < n {
            let len = mb.min(n - off);
            let x = feats.slice_batch(off, len).pad_batch(mb);
            let mut ybuf = vec![0i32; mb];
            ybuf[..len].copy_from_slice(&labels[off..off + len]);
            let y = Tensor::from_i32(vec![mb], &ybuf);
            let mut mask = vec![0.0f32; mb];
            mask[..len].iter_mut().for_each(|m| *m = 1.0);
            let mask = Tensor::from_f32(vec![mb], &mask);
            let t0 = Instant::now();
            let (grads, loss, correct) =
                self.arts.train_grads(&x, &y, &mask, &tail)?;
            // Training compute on a weak client is modeled like its
            // dominating dense kind (fully-connected backward).
            self.device_kind
                .charge(crate::model::UnitKind::Fc, t0.elapsed());
            loss_sum += loss;
            correct_sum += correct;
            match grad_sums.as_mut() {
                Some(acc) => ModelArtifacts::accumulate(acc, &grads)?,
                None => grad_sums = Some(grads),
            }
            off += len;
        }
        if let Some(grads) = grad_sums {
            let new_tail = self.arts.apply_update(
                self.cfg.learning_rate,
                n as f32,
                &tail,
                &grads,
            )?;
            *tail = new_tail;
        }
        Ok((loss_sum / n as f32, correct_sum / n as f32))
    }

    /// Train one epoch over the dataset; `labels` in global sample order.
    pub fn train_epoch(&self, ds: &DatasetRef, labels: &[i32]) -> Result<EpochStats> {
        if labels.len() != ds.num_samples {
            return Err(Error::other("labels/dataset size mismatch"));
        }
        // Pre-flight memory check: a batch that can never fit the client
        // device fails immediately (CUDA would crash on the first
        // iteration's first allocation; failing before the transfer
        // avoids paying for bytes a doomed epoch would stream).
        let need = self.app.memory().client_bytes(
            self.split.split_idx,
            self.cfg.train_batch.min(ds.num_samples),
        );
        if need > self.device.usable() {
            return Err(Error::Oom {
                needed: need,
                free: self.device.usable(),
                capacity: self.device.capacity(),
            });
        }
        let shards_per_iter =
            (self.cfg.train_batch / ds.shard_samples).max(1);
        let mut stats = EpochStats::default();
        let tx0 = self.link.stats().tx_bytes();
        let rx0 = self.link.stats().rx_bytes();

        let iterations: Vec<Vec<usize>> = (0..ds.num_shards)
            .collect::<Vec<_>>()
            .chunks(shards_per_iter)
            .map(|c| c.to_vec())
            .collect();

        // Double buffering: prefetch iteration k+1 while computing k.
        let mut pending: Option<Result<Tensor>> = None;
        for (it, shards) in iterations.iter().enumerate() {
            let t_fetch = Instant::now();
            let feats = match pending.take() {
                Some(f) => f?,
                None => self.fetch_features(ds, shards)?,
            };
            stats.comm += t_fetch.elapsed();

            let next = iterations.get(it + 1).cloned();
            let t_comp = Instant::now();
            let (loss, acc) = std::thread::scope(|scope| {
                let prefetch = next.map(|shards| {
                    scope.spawn(move || self.fetch_features(ds, &shards))
                });
                let first = shards[0] * ds.shard_samples;
                let count: usize = shards
                    .iter()
                    .map(|&s| {
                        ds.shard_samples
                            .min(ds.num_samples - s * ds.shard_samples)
                    })
                    .sum();
                let out =
                    self.compute_iteration(feats, &labels[first..first + count]);
                if let Some(p) = prefetch {
                    pending = Some(p.join().expect("prefetch panicked"));
                }
                out
            })?;
            stats.comp += t_comp.elapsed();
            stats.iterations += 1;
            stats.loss.push(loss);
            stats.accuracy.push(acc);
        }
        stats.bytes_to_cos = self.link.stats().tx_bytes() - tx0;
        stats.bytes_from_cos = self.link.stats().rx_bytes() - rx0;
        Ok(stats)
    }

    /// Bytes transferred per iteration at the current split (analytic).
    pub fn planned_bytes_per_iteration(&self) -> u64 {
        self.split.bytes_per_iteration
    }
}

#[cfg(test)]
mod tests {
    // HapiClient is integration-tested end to end in rust/tests/ (it
    // needs artifacts + a running proxy); unit tests cover dataset.rs.
}
