//! The Hapi client — the compute-tier half of the system (§5.2, §5.4).
//!
//! Per application it profiles the model (§5.3; the static profile comes
//! from the AOT metadata), chooses the split index (Algorithm 1), then
//! trains through the [`pipeline`] sharded prefetch engine: a
//! configurable-depth sliding window of training iterations is kept in
//! flight against the COS, each iteration's shards fanned out over a
//! `fetch_fanout`-sized pool of persistent connections (one POST per
//! storage object, or GETs for the BASELINE), results are reordered
//! into shard then submission order (preserving the learning trajectory
//! bit-for-bit at any fanout × depth), and the trainer consumes them
//! on the calling thread — leftover frozen units `[split+1, freeze]` at
//! the *training* batch size, then gradient accumulation over
//! micro-batches + one SGD update, numerically a full-batch step (see
//! `python/compile/model.py`).
//!
//! Depth 1 is the paper's double buffering; deeper windows hide
//! per-request COS latency behind compute (`pipeline_depth` in
//! [`HapiConfig`]).  With `adaptive_split` on, the client re-measures
//! the link bandwidth per delivery window and re-runs Algorithm 1
//! between iterations on windows where the trainer stalled on the
//! network, moving the split toward the freeze layer as bandwidth
//! shrinks (Table 4 dynamics) — never past it, and never earlier than
//! the initial (memory-checked) decision.
//!
//! Execution goes through [`ExecBackend`]: real AOT HLO via PJRT, or
//! the artifact-free SimBackend (identical orchestration, deterministic
//! values) — which is how the pipeline's invariants are tested without
//! `make artifacts`.

pub mod dataset;
pub mod pipeline;
pub mod transport;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::HapiConfig;
use crate::cos::protocol::{ConnOpts, CosConnection};
use crate::error::{Error, Result};
use crate::metrics::{names, Registry};
use crate::netsim::Topology;
use crate::policy::{self, SplitPolicy, SplitSignals, TraceSink};
use crate::profiler::AppProfile;
use crate::runtime::{DeviceKind, DeviceSim, ExecBackend, Tensor};
use crate::server::request::{PostRequest, RequestMode};
use crate::split::{self, SplitDecision};

pub use dataset::{DatasetRef, DatasetSpec};
pub use pipeline::{
    Delivery, Fetched, Job, PipelineReport, ShardCtx, ShardFetched,
    StaticTransport, Transport,
};
pub use transport::TransportScheduler;

/// Outcome of one epoch.
#[derive(Debug, Clone, Default)]
pub struct EpochStats {
    pub iterations: usize,
    pub loss: Vec<f32>,
    pub accuracy: Vec<f32>,
    /// Wall time blocked on network+COS results (per-iteration stalls).
    pub comm: Duration,
    /// Wall time computing locally (per iteration sums).
    pub comp: Duration,
    pub bytes_from_cos: u64,
    pub bytes_to_cos: u64,
    /// Split index each iteration trained at (changes only with
    /// `adaptive_split`; never exceeds the freeze index).
    pub splits: Vec<usize>,
    /// High-water mark of in-flight prefetched iterations (bounded by
    /// `pipeline_depth`).
    pub max_inflight: usize,
}

impl EpochStats {
    pub fn mean_loss(&self) -> f32 {
        if self.loss.is_empty() {
            0.0
        } else {
            self.loss.iter().sum::<f32>() / self.loss.len() as f32
        }
    }

    pub fn final_loss(&self) -> f32 {
        self.loss.last().copied().unwrap_or(0.0)
    }
}

/// Allocator for process-unique client identities (the planner's gather
/// lanes are keyed by them).  Ids start at 1: 0 means "unreported" on
/// the wire and maps to the planner's shared legacy lane.
static NEXT_CLIENT_ID: AtomicU64 = AtomicU64::new(1);

/// The stable identity a client reports to the storage-side planner:
/// the configured `client_id` when set, else a fresh process-unique id
/// (each constructed client is its own tenant).
pub(crate) fn resolve_client_id(cfg: &HapiConfig) -> u64 {
    match cfg.client_id {
        0 => NEXT_CLIENT_ID.fetch_add(1, Ordering::Relaxed),
        id => id,
    }
}

/// The *static* network path a pooled connection slot pins to: slots
/// round-robin over the topology's paths, rotated by the client's id so
/// single-connection tenants spread across front ends instead of all
/// hammering path 0.  Deterministic per (client, slot) — pin
/// `client_id` to pin a tenant's path.
///
/// This is the seed (and, with `repin_threshold_pct = 0`, the entire
/// behaviour) of the goodput-aware [`TransportScheduler`]'s dynamic
/// slot→path map; the per-path accounting (`pipeline.path<i>.*`) lives
/// in the scheduler too, shared by every client.
pub(crate) fn path_for_slot(
    client_id: u64,
    num_paths: usize,
    slot: usize,
) -> usize {
    (client_id as usize).wrapping_add(slot) % num_paths.max(1)
}

/// Run the configured split policy over fresh signals and record the
/// decision (trace line + `pipeline.policy_decisions`).  Shared by the
/// initial (construction-time) decision and the adaptive per-window
/// re-decision, so both route through the same [`SplitPolicy`].
fn run_split_policy(
    split_policy: &dyn SplitPolicy,
    trace: Option<&TraceSink>,
    registry: &Registry,
    app: &AppProfile,
    bandwidth: Option<u64>,
    cfg: &HapiConfig,
) -> usize {
    let sig = SplitSignals::from_app(
        app,
        bandwidth,
        cfg.split_window_secs,
        cfg.train_batch,
        cfg.pipeline_depth,
    );
    let idx = split_policy.choose(&sig);
    if let Some(t) = trace {
        t.record(
            "split",
            split_policy.name(),
            sig.to_json(),
            policy::split_decision_json(idx),
        );
    }
    registry.counter(names::PIPELINE_POLICY_DECISIONS).inc();
    idx
}

pub struct HapiClient {
    pub app: AppProfile,
    /// The initial (Algorithm 1) decision; `adaptive_split` re-decides
    /// per window at runtime without mutating this record.
    pub split: SplitDecision,
    backend: ExecBackend,
    cfg: HapiConfig,
    /// One proxy address per network path, index-aligned with `net`.
    addrs: Vec<String>,
    net: Topology,
    device_kind: DeviceKind,
    device: Arc<DeviceSim>,
    tail_params: Mutex<Vec<Tensor>>,
    next_req_id: AtomicU64,
    /// Stable identity reported in every POST header so the planner
    /// gathers this client's burst in its own lane.
    client_id: u64,
    registry: Registry,
    /// The split decision rule (`split_policy` knob; Algorithm 1 by
    /// default), shared by the initial and the adaptive re-decisions.
    split_policy: Box<dyn SplitPolicy>,
    /// Decision-trace sink (`decision_trace` knob; `None` = off).
    trace: Option<Arc<TraceSink>>,
}

impl HapiClient {
    /// General constructor over any execution backend.  `split_override`
    /// forces a split index (the §7.3 static-freeze competitor); `None`
    /// runs the configured [`SplitPolicy`] (Algorithm 1 by default).
    pub fn from_backend(
        app: AppProfile,
        backend: ExecBackend,
        cfg: HapiConfig,
        addrs: Vec<String>,
        net: Topology,
        device_kind: DeviceKind,
        split_override: Option<usize>,
    ) -> HapiClient {
        let split = split_override.map(|idx| SplitDecision {
            split_idx: idx,
            out_bytes_per_sample: app.out_bytes(idx),
            bytes_per_iteration: app.out_bytes(idx)
                * cfg.train_batch as u64,
            candidates: vec![idx],
        });
        Self::assemble(app, backend, cfg, addrs, net, device_kind, split)
    }

    /// The §7 BASELINE over any backend: stream raw images with GETs and
    /// run the whole network on the compute tier.  Encoded as split
    /// index 0 (no units pushed down); everything else — pipelining,
    /// training, memory accounting — is shared with the Hapi path,
    /// mirroring §6's "users provide the same training parameters in
    /// both cases".
    pub fn from_backend_baseline(
        app: AppProfile,
        backend: ExecBackend,
        cfg: HapiConfig,
        addrs: Vec<String>,
        net: Topology,
        device_kind: DeviceKind,
    ) -> HapiClient {
        let split = SplitDecision {
            split_idx: 0,
            out_bytes_per_sample: app.input_bytes(),
            bytes_per_iteration: app.input_bytes() * cfg.train_batch as u64,
            candidates: vec![],
        };
        Self::assemble(
            app,
            backend,
            cfg,
            addrs,
            net,
            device_kind,
            Some(split),
        )
    }

    /// `split: None` runs the configured split policy for the initial
    /// decision; `Some` (static freeze / BASELINE) bypasses it — those
    /// competitors make no decision worth recording.
    fn assemble(
        app: AppProfile,
        backend: ExecBackend,
        cfg: HapiConfig,
        addrs: Vec<String>,
        net: Topology,
        device_kind: DeviceKind,
        split: Option<SplitDecision>,
    ) -> HapiClient {
        assert!(
            !addrs.is_empty(),
            "client needs at least one proxy address"
        );
        let device =
            DeviceSim::new("client-dev", device_kind, cfg.client_gpu_mem, 0);
        let tail_params = Mutex::new(backend.initial_tail_params());
        let client_id = resolve_client_id(&cfg);
        // Config validation rejects unknown names before a client is
        // built; the fallback keeps construction infallible.
        let split_policy = policy::split_policy(&cfg.split_policy)
            .unwrap_or_else(|_| Box::new(policy::AnalyticSplit));
        let trace = policy::sink_for(&cfg.decision_trace);
        let registry = Registry::new();
        let split = split.unwrap_or_else(|| {
            let idx = run_split_policy(
                split_policy.as_ref(),
                trace.as_deref(),
                &registry,
                &app,
                // Algorithm 1 sees the whole storage network: summed
                // path rates, clamped by the client-NIC cap.
                net.total_rate(),
                &cfg,
            );
            split::decision_for(&app, idx, cfg.train_batch)
        });
        HapiClient {
            app,
            split,
            backend,
            cfg,
            addrs,
            net,
            device_kind,
            device,
            tail_params,
            next_req_id: AtomicU64::new(1),
            client_id,
            registry,
            split_policy,
            trace,
        }
    }

    /// The identity this client reports to the planner's gather lanes
    /// (keys the `ba.lane.<id>.*` metrics on the server side).
    pub fn client_id(&self) -> u64 {
        self.client_id
    }

    /// Route the client's pipeline metrics into a shared registry (the
    /// harness points this at the testbed's).
    pub fn set_registry(&mut self, registry: Registry) {
        self.registry = registry;
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn device(&self) -> &Arc<DeviceSim> {
        &self.device
    }

    fn req_id(&self) -> u64 {
        self.next_req_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Fetch one shard at `split` over the pooled connection in `slot`,
    /// routed to network `path` (its link and its proxy front end; the
    /// connection is lazily connected, one that errored is dropped so
    /// the slot reconnects on its next use — this is what makes the
    /// engine's retry land on a *healthy* link — and a slot the
    /// scheduler re-pinned to another path reconnects to the new
    /// front end).  Hapi mode (split ≥ 1) POSTs a feature-extraction
    /// request; BASELINE (split 0) GETs the raw image object.
    /// `burst_width` tells the storage-side planner how many requests
    /// this client keeps in flight (`pipeline_depth × shards_per_iter`)
    /// and `client_id` which gather lane they belong to, so the
    /// planner adapts this client's window to its burst without
    /// holding up co-tenants.
    #[allow(clippy::too_many_arguments)]
    fn fetch_shard_on(
        &self,
        ds: &DatasetRef,
        shard: usize,
        split: usize,
        burst_width: usize,
        slot: &Mutex<Option<(usize, CosConnection)>>,
        path: usize,
    ) -> Result<Tensor> {
        let samples = ds
            .shard_samples
            .min(ds.num_samples - shard * ds.shard_samples);
        let mut dims = vec![samples];
        dims.extend(&ds.input_shape);
        let key = crate::cos::ObjectKey::shard(&ds.name, shard);
        let addr = &self.addrs[path % self.addrs.len()];
        let link = self.net.path(path);
        let opts = ConnOpts::from_cfg(
            self.cfg.io_deadline_ms,
            self.cfg.frame_integrity,
        );
        // Bounded admission maps to retry-with-backoff: a planner
        // `Busy` reject is backpressure, not a fault — back off
        // (2 ms doubling, 100 ms cap) and re-offer the request instead
        // of waiting forever in a queue the server chose to bound.
        // Integrity failures share the loop: a corrupted frame is
        // transient per-frame noise, so re-sending on the same path is
        // the right remedy.  Timeouts deliberately do NOT retry here —
        // a stall is path-sticky, so they propagate to the sharded
        // engine, whose retry re-routes to another connection/path.
        let policy = crate::util::retry::RetryPolicy::backoff(
            8,
            std::time::Duration::from_millis(2),
            std::time::Duration::from_millis(100),
        )
        .jitter(
            self.cfg
                .seed
                .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                .wrapping_add(self.client_id | 1),
        );
        crate::util::retry::run(
            &policy,
            |e| e.is_rejected() || e.is_integrity(),
            |_, e| {
                if e.is_rejected() {
                    self.registry
                        .counter(names::PIPELINE_ADMIT_RETRIES)
                        .inc();
                }
            },
            |_| {
                let res = CosConnection::with_pooled_opts(
                    slot,
                    path,
                    addr,
                    link,
                    opts,
                    |conn| {
                    if split == 0 {
                        let body = conn.get(&key)?;
                        return Tensor::from_raw(
                            crate::runtime::DType::F32,
                            dims.clone(),
                            body,
                        );
                    }
                    let mem = self.app.memory();
                    let req = PostRequest {
                        id: self.req_id(),
                        model: self.app.model.name.clone(),
                        split_idx: split,
                        object: key.clone(),
                        labels_object: String::new(),
                        input_dims: dims.clone(),
                        b_max: self.cfg.object_samples.min(samples),
                        mem_data_per_sample: mem
                            .fe_data_bytes_per_sample(split),
                        mem_model_bytes: mem.fe_model_bytes(split),
                        burst_width,
                        client_id: self.client_id,
                        mode: RequestMode::FeatureExtract,
                    };
                    let (header, body) =
                        conn.post(req.to_json(), Vec::new())?;
                    let out_dims =
                        header.get("out_dims")?.as_usize_vec()?;
                    Tensor::from_raw(
                        crate::runtime::DType::F32,
                        out_dims,
                        body,
                    )
                },
                );
                if let Err(e) = &res {
                    if e.is_timeout() {
                        self.registry
                            .counter(names::PIPELINE_TIMEOUTS)
                            .inc();
                    } else if e.is_integrity() {
                        self.registry
                            .counter(names::PIPELINE_INTEGRITY_FAIL)
                            .inc();
                    }
                }
                res
            },
        )
    }

    /// Compute phase for one iteration: leftover frozen units at the
    /// training batch size, then grad accumulation + one SGD update.
    /// `split` is the index this iteration's features were extracted at
    /// (it can differ across iterations under `adaptive_split`).
    fn compute_iteration(
        &self,
        split: usize,
        feats: Tensor,
        labels: &[i32],
    ) -> Result<(f32, f32)> {
        let freeze = self.app.freeze_idx();
        let mem = self.app.memory();
        let _lease = self
            .device
            .admit(mem.client_bytes(split, feats.dims[0]))?;

        let feats = if split < freeze {
            self.backend.forward_segment(
                &feats,
                split + 1,
                freeze,
                self.device_kind,
                None,
            )?
        } else {
            feats
        };

        let mb = self.backend.micro_batch();
        let n = feats.dims[0];
        debug_assert_eq!(n, labels.len());
        let mut tail = self.tail_params.lock().unwrap();
        let mut grad_sums: Option<Vec<Tensor>> = None;
        let mut loss_sum = 0.0f32;
        let mut correct_sum = 0.0f32;
        let mut off = 0;
        while off < n {
            let len = mb.min(n - off);
            let x = feats.slice_batch(off, len).pad_batch(mb);
            let mut ybuf = vec![0i32; mb];
            ybuf[..len].copy_from_slice(&labels[off..off + len]);
            let y = Tensor::from_i32(vec![mb], &ybuf);
            let mut mask = vec![0.0f32; mb];
            mask[..len].iter_mut().for_each(|m| *m = 1.0);
            let mask = Tensor::from_f32(vec![mb], &mask);
            let t0 = Instant::now();
            let (grads, loss, correct) =
                self.backend.train_grads(&x, &y, &mask, &tail)?;
            // Training compute on a weak client is modeled like its
            // dominating dense kind (fully-connected backward).
            self.device_kind
                .charge(crate::model::UnitKind::Fc, t0.elapsed());
            loss_sum += loss;
            correct_sum += correct;
            match grad_sums.as_mut() {
                Some(acc) => ExecBackend::accumulate(acc, &grads)?,
                None => grad_sums = Some(grads),
            }
            off += len;
        }
        if let Some(grads) = grad_sums {
            let new_tail = self.backend.apply_update(
                self.cfg.learning_rate,
                n as f32,
                &tail,
                &grads,
            )?;
            *tail = new_tail;
        }
        Ok((loss_sum / n as f32, correct_sum / n as f32))
    }

    /// Train one epoch over the dataset; `labels` in global sample order.
    ///
    /// Iterations flow through the [`pipeline`] engine: `pipeline_depth`
    /// iterations are prefetched against the COS while earlier ones
    /// compute, delivered strictly in order.
    pub fn train_epoch(&self, ds: &DatasetRef, labels: &[i32]) -> Result<EpochStats> {
        self.train_epoch_inner(ds, labels, None)
    }

    /// [`HapiClient::train_epoch`] with a scripted tenant crash: the
    /// epoch aborts with an error after `abort_after` delivered
    /// iterations (`None` = run to completion).  Exists for the churn
    /// suite — a tenant dying mid-epoch abandons whatever it still has
    /// queued in the storage-side planner, and the planner must reap
    /// those waiters rather than leak lanes, leases, and metrics.
    pub fn train_epoch_limited(
        &self,
        ds: &DatasetRef,
        labels: &[i32],
        abort_after: Option<usize>,
    ) -> Result<EpochStats> {
        self.train_epoch_inner(ds, labels, abort_after)
    }

    fn train_epoch_inner(
        &self,
        ds: &DatasetRef,
        labels: &[i32],
        abort_after: Option<usize>,
    ) -> Result<EpochStats> {
        if labels.len() != ds.num_samples {
            return Err(Error::other("labels/dataset size mismatch"));
        }
        // Pre-flight memory check: a batch that can never fit the client
        // device fails immediately (CUDA would crash on the first
        // iteration's first allocation; failing before the transfer
        // avoids paying for bytes a doomed epoch would stream).  The
        // initial split is the most client-memory-hungry one admitted:
        // the adaptive re-decision below is clamped to never move the
        // split earlier than it (later splits push more down and leave
        // fewer leftover units on the client).
        let need = self.app.memory().client_bytes(
            self.split.split_idx,
            self.cfg.train_batch.min(ds.num_samples),
        );
        if need > self.device.usable() {
            return Err(Error::Oom {
                needed: need,
                free: self.device.usable(),
                capacity: self.device.capacity(),
            });
        }
        let shards_per_iter =
            (self.cfg.train_batch / ds.shard_samples).max(1);
        let jobs = pipeline::jobs_for(ds.num_shards, shards_per_iter);
        let fanout = self.cfg.resolved_fanout(shards_per_iter);
        let burst_width = pipeline::planner_burst_width(
            self.cfg.pipeline_depth,
            shards_per_iter,
            fanout,
        );

        let mut stats = EpochStats::default();
        let tx0 = self.net.stats().tx_bytes();
        let rx0 = self.net.stats().rx_bytes();

        // Split shared between the trainer (re-decides) and the fetch
        // workers (sampled once per iteration when it enters the window,
        // so all shards of one training batch share a split).
        let cur_split = AtomicUsize::new(self.split.split_idx);
        let adaptive =
            self.cfg.adaptive_split && self.split.split_idx >= 1;
        // Connection pool: `fanout` lazily-connected slots, reused
        // across shards and iterations (multi-link fetch); a connection
        // that errored is dropped and its slot reconnects.  Each slot
        // is routed to one network path (and that path's proxy front
        // end) by the transport scheduler — statically pre-pinned
        // round-robin, re-pinned away from low-goodput paths when
        // `repin_threshold_pct` is set; with several paths the shard
        // fanout turns into genuine multi-NIC parallelism.
        let pool: Vec<Mutex<Option<(usize, CosConnection)>>> =
            (0..fanout).map(|_| Mutex::new(None)).collect();
        // The goodput-aware transport policy for this epoch: per-path
        // goodput/latency estimators fed by every shard completion,
        // the dynamic slot→path map, the hedge budget, and the
        // `pipeline.pathN.*` accounting whose merged sum drives the
        // per-window bandwidth re-measurement below (exactly as the
        // per-connection samples did pre-topology).
        let scheduler = TransportScheduler::new(
            &self.cfg,
            self.client_id,
            &self.net,
            fanout,
            &self.registry,
        );
        // Per-window bandwidth re-measurement state (trainer-side).
        let mut win_rx = 0u64;
        let mut win_t = Instant::now();

        let report = pipeline::run_sharded_with(
            self.cfg.pipeline_depth,
            fanout,
            &jobs,
            &self.registry,
            true,
            &scheduler,
            |_job| cur_split.load(Ordering::Relaxed),
            |ctx, &split, job, shard_pos| {
                let tensor = self.fetch_shard_on(
                    ds,
                    job.shards[shard_pos],
                    split,
                    burst_width,
                    &pool[ctx.conn],
                    ctx.path,
                )?;
                let bytes = tensor.byte_len() as u64;
                Ok(pipeline::ShardFetched {
                    payload: tensor,
                    bytes,
                })
            },
            |_job, &split, parts| {
                // Reorder: shard order == training-batch order,
                // regardless of per-connection completion order (§5.2's
                // reorder buffer, shard level).
                let tensor = Tensor::concat_batch(&parts)?;
                Ok((tensor, split))
            },
            |delivery| {
                // Scripted tenant crash: die before consuming this
                // delivery, leaving in-flight planner work abandoned.
                if abort_after == Some(stats.iterations) {
                    return Err(Error::other(
                        "tenant crashed (scripted)",
                    ));
                }
                let (feats, split) = delivery.payload;
                stats.comm += delivery.stall;
                let shards = &jobs[delivery.seq].shards;
                let first = shards[0] * ds.shard_samples;
                let count: usize = shards
                    .iter()
                    .map(|&s| {
                        ds.shard_samples
                            .min(ds.num_samples - s * ds.shard_samples)
                    })
                    .sum();
                let t_comp = Instant::now();
                let (loss, acc) = self.compute_iteration(
                    split,
                    feats,
                    &labels[first..first + count],
                )?;
                self.registry
                    .histogram(names::PIPELINE_COMPUTE_NS)
                    .record(t_comp.elapsed().as_nanos() as u64);
                stats.comp += t_comp.elapsed();
                stats.iterations += 1;
                stats.loss.push(loss);
                stats.accuracy.push(acc);
                stats.splits.push(split);

                if adaptive {
                    // Re-measure the link over the delivery window and
                    // re-run Algorithm 1 (Table 4 dynamics).  The
                    // per-path samples are merged (summed) into one
                    // window measurement — it observes goodput across
                    // every live path, not per-path shares, so a
                    // single degraded path shows up as a proportional
                    // aggregate drop.  Two guards keep the estimate
                    // honest:
                    //
                    // - only *stalled* windows re-decide: when the
                    //   trainer never waited on the network, the link
                    //   was demand-limited (idle during compute), the
                    //   measurement reflects demand rather than
                    //   availability, and bandwidth is not the
                    //   bottleneck anyway;
                    // - the new split is clamped to never move earlier
                    //   than the initial decision: the pre-flight
                    //   memory check admitted the initial split, and
                    //   every later split needs *less* client memory.
                    let now = Instant::now();
                    let dt = now.duration_since(win_t).as_secs_f64();
                    let rx: u64 = scheduler.rx_bytes();
                    if dt >= 0.01 && rx > win_rx {
                        let stalled =
                            delivery.stall.as_secs_f64() >= 0.1 * dt;
                        let bw = ((rx - win_rx) as f64 / dt).max(1.0);
                        win_rx = rx;
                        win_t = now;
                        if stalled {
                            let idx = run_split_policy(
                                self.split_policy.as_ref(),
                                self.trace.as_deref(),
                                &self.registry,
                                &self.app,
                                Some(bw as u64),
                                &self.cfg,
                            );
                            let new = idx.max(self.split.split_idx);
                            let old = cur_split.load(Ordering::Relaxed);
                            if new != old {
                                cur_split.store(new, Ordering::Relaxed);
                                self.registry
                                    .counter(names::PIPELINE_SPLIT_REDECISIONS)
                                    .inc();
                            }
                        }
                    }
                }
                Ok(())
            },
        )?;
        stats.max_inflight = report.inflight_max;
        stats.bytes_to_cos = self.net.stats().tx_bytes() - tx0;
        stats.bytes_from_cos = self.net.stats().rx_bytes() - rx0;
        Ok(stats)
    }

    /// Bytes transferred per iteration at the current split (analytic).
    pub fn planned_bytes_per_iteration(&self) -> u64 {
        self.split.bytes_per_iteration
    }
}

#[cfg(test)]
mod tests {
    // HapiClient is integration-tested end to end: rust/tests/
    // stack_integration.rs (HLO backend; needs artifacts + a proxy) and
    // rust/tests/sim_backend.rs (SimBackend; artifact-free).  The
    // pipeline engine has its own unit + property tests (pipeline.rs,
    // rust/tests/pipeline_props.rs); unit tests here cover dataset.rs.
}
