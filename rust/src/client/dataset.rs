//! Synthetic datasets: generation, sharding, and upload to the COS.
//!
//! The paper streams ImageNet shards of 1000 images per object; we
//! generate a learnable synthetic classification task with the same
//! layout (100 samples per object at tiny scale).  Each class has a
//! random template; a sample is `template[class] + noise`, which the
//! training tail can separate — the end-to-end example's loss visibly
//! falls (EXPERIMENTS.md §E2E).
//!
//! Shard objects store raw f32 tensor bytes `[samples, C, H, W]`; label
//! shards store raw i32 `[samples]` next to them, so ALL_IN_COS jobs can
//! train server-side and clients can GET the (tiny) label objects.

use std::sync::Arc;

use crate::cos::storage::StorageCluster;
use crate::cos::{Object, ObjectKey};
use crate::error::Result;
use crate::runtime::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: String,
    pub input_shape: Vec<usize>, // (C, H, W)
    pub num_classes: usize,
    pub num_samples: usize,
    pub shard_samples: usize,
    pub seed: u64,
}

#[derive(Debug, Clone)]
pub struct DatasetRef {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub num_samples: usize,
    pub shard_samples: usize,
    pub num_shards: usize,
}

impl DatasetSpec {
    pub fn shard_key(&self, i: usize) -> ObjectKey {
        ObjectKey::shard(&self.name, i)
    }

    pub fn labels_key(&self, i: usize) -> ObjectKey {
        ObjectKey::new(format!("{}/labels_{i:05}", self.name))
    }

    pub fn num_shards(&self) -> usize {
        self.num_samples.div_ceil(self.shard_samples)
    }

    pub fn to_ref(&self) -> DatasetRef {
        DatasetRef {
            name: self.name.clone(),
            input_shape: self.input_shape.clone(),
            num_samples: self.num_samples,
            shard_samples: self.shard_samples,
            num_shards: self.num_shards(),
        }
    }

    /// Generate + store all shards directly into the cluster (benches and
    /// the server-side of experiments; uploads through the proxy should
    /// use [`upload`]).
    pub fn materialize(&self, cluster: &Arc<StorageCluster>) -> Result<DatasetRef> {
        for (i, (images, labels)) in self.shards().enumerate() {
            cluster.put(Object::new(self.shard_key(i), images.into_raw()));
            let label_bytes: Vec<u8> = labels
                .iter()
                .flat_map(|l| l.to_le_bytes())
                .collect();
            cluster.put(Object::new(self.labels_key(i), label_bytes));
        }
        Ok(self.to_ref())
    }

    /// Iterator over generated shards `(images, labels)`.
    pub fn shards(&self) -> impl Iterator<Item = (Tensor, Vec<i32>)> + '_ {
        let sample_elems: usize = self.input_shape.iter().product();
        // Class templates: one random pattern per class.
        let mut trng = Rng::new(self.seed ^ 0xDA7A);
        let templates: Vec<Vec<f32>> = (0..self.num_classes)
            .map(|_| (0..sample_elems).map(|_| trng.normal()).collect())
            .collect();
        (0..self.num_shards()).map(move |shard| {
            let mut rng = Rng::new(self.seed.wrapping_add(shard as u64 * 7919));
            let n = self
                .shard_samples
                .min(self.num_samples - shard * self.shard_samples);
            let mut data = Vec::with_capacity(n * sample_elems);
            let mut labels = Vec::with_capacity(n);
            for _ in 0..n {
                let class = rng.usize_below(self.num_classes);
                labels.push(class as i32);
                let t = &templates[class];
                for e in t.iter().take(sample_elems) {
                    data.push(0.7 * e + 0.5 * rng.normal());
                }
            }
            let mut dims = vec![n];
            dims.extend(&self.input_shape);
            (Tensor::from_f32(dims, &data), labels)
        })
    }

    /// Fetch all labels from the cluster in shard order.
    pub fn fetch_labels(
        ds: &DatasetRef,
        cluster: &Arc<StorageCluster>,
    ) -> Result<Vec<i32>> {
        let mut out = Vec::with_capacity(ds.num_samples);
        for i in 0..ds.num_shards {
            let key = ObjectKey::new(format!("{}/labels_{i:05}", ds.name));
            let obj = cluster.get(&key)?;
            out.extend(obj.data.chunks_exact(4).map(|c| {
                i32::from_le_bytes([c[0], c[1], c[2], c[3]])
            }));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> DatasetSpec {
        DatasetSpec {
            name: "test".into(),
            input_shape: vec![3, 4, 4],
            num_classes: 5,
            num_samples: 250,
            shard_samples: 100,
            seed: 1,
        }
    }

    #[test]
    fn shard_count_and_sizes() {
        let s = spec();
        assert_eq!(s.num_shards(), 3);
        let shards: Vec<_> = s.shards().collect();
        assert_eq!(shards[0].0.dims, vec![100, 3, 4, 4]);
        assert_eq!(shards[2].0.dims, vec![50, 3, 4, 4]); // partial tail
        assert_eq!(shards[2].1.len(), 50);
    }

    #[test]
    fn deterministic_generation() {
        let a: Vec<_> = spec().shards().collect();
        let b: Vec<_> = spec().shards().collect();
        assert_eq!(a[0].0, b[0].0);
        assert_eq!(a[1].1, b[1].1);
    }

    #[test]
    fn labels_in_range() {
        for (_imgs, labels) in spec().shards() {
            assert!(labels.iter().all(|&l| (0..5).contains(&l)));
        }
    }

    #[test]
    fn materialize_and_fetch() {
        let cluster = Arc::new(StorageCluster::new(3, 2));
        let s = spec();
        let ds = s.materialize(&cluster).unwrap();
        assert!(cluster.contains(&s.shard_key(0)));
        let labels = DatasetSpec::fetch_labels(&ds, &cluster).unwrap();
        assert_eq!(labels.len(), 250);
        let direct: Vec<i32> = s.shards().flat_map(|(_, l)| l).collect();
        assert_eq!(labels, direct);
    }
}
