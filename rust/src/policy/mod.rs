//! Pluggable decision policies, recorded decision traces and offline
//! policy replay.
//!
//! HAPI's three control decisions — split choice (Algorithm 1 in
//! [`crate::split`]), storage-side batch adaptation (Eq. 4 in
//! [`crate::batch`], driven by `server/planner.rs`) and transport
//! slot→path re-pinning (`client/transport.rs`) — were hard-coded
//! analytic solvers reading overlapping signals through private
//! plumbing.  This module factors each site into the BYOM shape (see
//! PAPERS.md): the *system* gathers a signals snapshot and applies the
//! decision, the *policy* maps signals → decision and is swappable per
//! deployment via the `split_policy` / `batch_policy` /
//! `transport_policy` knobs.  The analytic solvers stay the defaults
//! and remain byte-identical to the pre-refactor code (pinned by
//! `rust/tests/policy_golden.rs`).
//!
//! **Decision traces.**  With the `decision_trace` knob set to a file
//! path, every policy invocation appends a [`DecisionRecord`] —
//! timestamped signals-in + decision-out — as one compact JSON line.
//! All sites of one process share a [`TraceSink`] per path, so records
//! from the client, the transport scheduler and the planner interleave
//! under one global sequence number with line-atomic writes.
//!
//! **Offline replay.**  [`eval_trace`] replays a recorded trace against
//! a candidate [`PolicySet`] and scores it without a live run:
//! decision-match rate per site, plus a predicted-delta per the
//! `theory/` cost model (seconds of per-iteration transfer for split,
//! planned bytes for batch, differently-routed slots for transport).
//! `hapi policy-eval --trace <file> --policy <name>` is the CLI front
//! end.

use std::collections::BTreeMap;
use std::fs::OpenOptions;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::batch::{self, Assignment, BatchRequest, Solution};
use crate::error::{Error, Result};
use crate::profiler::AppProfile;
use crate::split;
use crate::theory;
use crate::util::json::Json;

/// Latency samples a path needs before its p95 estimate participates
/// in degradation detection (mirrors the hedger's sample floor).
pub const MIN_LAT_SAMPLES: u64 = 8;

// ---------------------------------------------------------------------
// Split
// ---------------------------------------------------------------------

/// Everything Algorithm 1 (or a replacement) may look at when choosing
/// a split index.
#[derive(Debug, Clone, PartialEq)]
pub struct SplitSignals {
    /// Application input bytes per sample (`L_0`).
    pub input_bytes: u64,
    /// Last frozen unit — the deepest admissible split.
    pub freeze_idx: usize,
    /// `out_bytes[i - 1]` = bytes/sample leaving unit `i` (1-based,
    /// up to the freeze index).
    pub out_bytes: Vec<u64>,
    /// Measured bandwidth in bytes/sec (`None` = unshaped/unknown).
    pub bandwidth: Option<u64>,
    /// The paper's "1 s" decision window.
    pub window_secs: f64,
    /// Training batch (scales per-sample outputs to per-iteration).
    pub train_batch: usize,
    /// The client's prefetch depth (context for non-analytic policies).
    pub pipeline_depth: usize,
}

impl SplitSignals {
    pub fn from_app(
        app: &AppProfile,
        bandwidth: Option<u64>,
        window_secs: f64,
        train_batch: usize,
        pipeline_depth: usize,
    ) -> SplitSignals {
        SplitSignals {
            input_bytes: app.input_bytes(),
            freeze_idx: app.freeze_idx(),
            out_bytes: (1..=app.freeze_idx()).map(|i| app.out_bytes(i)).collect(),
            bandwidth,
            window_secs,
            train_batch,
            pipeline_depth,
        }
    }

    pub fn to_json(&self) -> Json {
        let out = self.out_bytes.iter().map(|&b| Json::num(b as f64)).collect();
        Json::obj(vec![
            ("input_bytes", Json::num(self.input_bytes as f64)),
            ("freeze_idx", Json::num(self.freeze_idx as f64)),
            ("out_bytes", Json::Arr(out)),
            (
                "bandwidth",
                match self.bandwidth {
                    Some(bw) => Json::num(bw as f64),
                    None => Json::Null,
                },
            ),
            ("window_secs", Json::num(self.window_secs)),
            ("train_batch", Json::num(self.train_batch as f64)),
            ("pipeline_depth", Json::num(self.pipeline_depth as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SplitSignals> {
        let out_bytes = j
            .get("out_bytes")?
            .as_arr()?
            .iter()
            .map(|b| b.as_u64())
            .collect::<Result<Vec<u64>>>()?;
        Ok(SplitSignals {
            input_bytes: j.get("input_bytes")?.as_u64()?,
            freeze_idx: j.get("freeze_idx")?.as_usize()?,
            out_bytes,
            bandwidth: match j.get("bandwidth")? {
                Json::Null => None,
                bw => Some(bw.as_u64()?),
            },
            window_secs: j.get("window_secs")?.as_f64()?,
            train_batch: j.get("train_batch")?.as_usize()?,
            pipeline_depth: j.get("pipeline_depth")?.as_usize()?,
        })
    }
}

/// Signals → split index.  Implementations must stay pure (no side
/// effects): the same signals must yield the same decision, or the
/// offline replay scoring is meaningless.
pub trait SplitPolicy: Send + Sync {
    fn name(&self) -> &'static str;
    fn choose(&self, sig: &SplitSignals) -> usize;
}

/// The paper's Algorithm 1 (the default): earliest candidate whose
/// per-iteration transfer fits under `bandwidth × window`.
pub struct AnalyticSplit;

impl SplitPolicy for AnalyticSplit {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn choose(&self, sig: &SplitSignals) -> usize {
        split::choose_split_from(
            sig.input_bytes,
            sig.freeze_idx,
            &sig.out_bytes,
            sig.bandwidth,
            sig.window_secs,
            sig.train_batch,
        )
    }
}

/// Always split at the freeze index — the static-freeze competitor's
/// choice, and Algorithm 1's scarce-bandwidth fallback.
pub struct FreezeSplit;

impl SplitPolicy for FreezeSplit {
    fn name(&self) -> &'static str {
        "freeze"
    }

    fn choose(&self, sig: &SplitSignals) -> usize {
        sig.freeze_idx
    }
}

// ---------------------------------------------------------------------
// Batch
// ---------------------------------------------------------------------

/// One planning pass's view: the ready-lane requests (in lane-rank
/// order) and the device memory budget.
#[derive(Debug, Clone)]
pub struct BatchSignals {
    pub requests: Vec<BatchRequest>,
    /// Free device bytes this pass may plan into.
    pub budget: u64,
    /// Operator minimum batch (paper: 25).
    pub b_min: usize,
    /// Execution granularity (the AOT micro-batch).
    pub step: usize,
}

impl BatchSignals {
    pub fn to_json(&self) -> Json {
        let reqs = self
            .requests
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", Json::num(r.id as f64)),
                    ("data_bytes_per_sample", Json::num(r.data_bytes_per_sample as f64)),
                    ("model_bytes", Json::num(r.model_bytes as f64)),
                    ("b_max", Json::num(r.b_max as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("requests", Json::Arr(reqs)),
            ("budget", Json::num(self.budget as f64)),
            ("b_min", Json::num(self.b_min as f64)),
            ("step", Json::num(self.step as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<BatchSignals> {
        let requests = j
            .get("requests")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(BatchRequest {
                    id: r.get("id")?.as_u64()?,
                    data_bytes_per_sample: r.get("data_bytes_per_sample")?.as_u64()?,
                    model_bytes: r.get("model_bytes")?.as_u64()?,
                    b_max: r.get("b_max")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<BatchRequest>>>()?;
        Ok(BatchSignals {
            requests,
            budget: j.get("budget")?.as_u64()?,
            b_min: j.get("b_min")?.as_usize()?,
            step: j.get("step")?.as_usize()?,
        })
    }
}

/// Signals → per-lane grants.  [`Error::Infeasible`] means even one
/// request at its floor cannot fit (the planner skips the pass).
pub trait BatchPolicy: Send + Sync {
    fn name(&self) -> &'static str;
    fn plan(&self, sig: &BatchSignals) -> Result<Solution>;
}

/// The Eq. 4 water-filling solver (the default).
pub struct AnalyticBatch;

impl BatchPolicy for AnalyticBatch {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn plan(&self, sig: &BatchSignals) -> Result<Solution> {
        batch::solve(&sig.requests, sig.budget, sig.b_min, sig.step)
    }
}

/// Grant every request its floor batch (`min(b_min, b_max)`) and never
/// water-fill — a deliberately conservative baseline for policy-eval
/// comparisons.  Shares the solver's drop-tail behaviour when even the
/// floors do not fit.
pub struct FloorBatch;

impl BatchPolicy for FloorBatch {
    fn name(&self) -> &'static str {
        "floor"
    }

    fn plan(&self, sig: &BatchSignals) -> Result<Solution> {
        let reqs = &sig.requests;
        if reqs.is_empty() {
            return Ok(Solution {
                assignments: vec![],
                deferred: vec![],
                planned_bytes: 0,
            });
        }
        let floor_of = |r: &BatchRequest| {
            r.model_bytes + sig.b_min.min(r.b_max) as u64 * r.data_bytes_per_sample
        };
        let mut active = reqs.len();
        loop {
            let floor: u64 = reqs[..active].iter().map(floor_of).sum();
            if floor <= sig.budget {
                break;
            }
            active -= 1;
            if active == 0 {
                return Err(Error::Infeasible(format!(
                    "request {} needs {} bytes at b_min={}, budget {}",
                    reqs[0].id,
                    floor_of(&reqs[0]),
                    sig.b_min,
                    sig.budget
                )));
            }
        }
        let planned: u64 = reqs[..active].iter().map(floor_of).sum();
        Ok(Solution {
            assignments: reqs[..active]
                .iter()
                .map(|r| Assignment {
                    id: r.id,
                    batch: sig.b_min.min(r.b_max),
                })
                .collect(),
            deferred: reqs[active..].iter().map(|r| r.id).collect(),
            planned_bytes: planned,
        })
    }
}

// ---------------------------------------------------------------------
// Transport
// ---------------------------------------------------------------------

/// One path's estimator snapshot at decision time.
#[derive(Debug, Clone, PartialEq)]
pub struct PathSnapshot {
    pub path: usize,
    /// Goodput EWMA estimate, bytes/sec.
    pub goodput: f64,
    /// Configured healthy-baseline rate, bytes/sec (0 = unknown).
    pub seed: f64,
    /// p95 fetch-latency estimate in ns (EWMA mean + 2·deviation).
    pub p95_ns: u64,
    /// Estimator samples folded in so far — latency samples land even
    /// for zero-payload responses, so ALL_IN_COS streams count here.
    pub samples: u64,
}

/// The uniform signals view a transport policy decides from: per-path
/// goodput/p95/sample snapshots plus the current and home slot maps.
#[derive(Debug, Clone, PartialEq)]
pub struct TransportSignals {
    pub paths: Vec<PathSnapshot>,
    /// Current slot→path map.
    pub slot_paths: Vec<usize>,
    /// Each slot's static home path.
    pub home_paths: Vec<usize>,
    /// The `repin_threshold_pct` knob (1..=100 while re-pinning is on).
    pub threshold_pct: u64,
}

impl TransportSignals {
    pub fn to_json(&self) -> Json {
        let paths = self
            .paths
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("path", Json::num(p.path as f64)),
                    ("goodput", Json::num(p.goodput)),
                    ("seed", Json::num(p.seed)),
                    ("p95_ns", Json::num(p.p95_ns as f64)),
                    ("samples", Json::num(p.samples as f64)),
                ])
            })
            .collect();
        let slot_paths = self.slot_paths.iter().map(|&p| Json::num(p as f64)).collect();
        let home_paths = self.home_paths.iter().map(|&p| Json::num(p as f64)).collect();
        Json::obj(vec![
            ("paths", Json::Arr(paths)),
            ("slot_paths", Json::Arr(slot_paths)),
            ("home_paths", Json::Arr(home_paths)),
            ("threshold_pct", Json::num(self.threshold_pct as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TransportSignals> {
        let paths = j
            .get("paths")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(PathSnapshot {
                    path: p.get("path")?.as_usize()?,
                    goodput: p.get("goodput")?.as_f64()?,
                    seed: p.get("seed")?.as_f64()?,
                    p95_ns: p.get("p95_ns")?.as_u64()?,
                    samples: p.get("samples")?.as_u64()?,
                })
            })
            .collect::<Result<Vec<PathSnapshot>>>()?;
        Ok(TransportSignals {
            paths,
            slot_paths: j.get("slot_paths")?.as_usize_vec()?,
            home_paths: j.get("home_paths")?.as_usize_vec()?,
            threshold_pct: j.get("threshold_pct")?.as_u64()?,
        })
    }
}

/// Why a slot moves — drives the scheduler's metric attribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RepinKind {
    /// Slot leaves a degraded path (counted in `pipeline.repins`).
    Evacuate,
    /// Slot returns to its recovered static home (counted in both
    /// `pipeline.repins` and `pipeline.repins_back`).
    MigrateBack,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RepinMove {
    pub slot: usize,
    pub path: usize,
    pub kind: RepinKind,
}

/// Signals → slot moves.  The scheduler applies the moves verbatim and
/// owns all gating (knob off, interval amortisation), so a policy is
/// only consulted while re-pinning is enabled.
pub trait TransportPolicy: Send + Sync {
    fn name(&self) -> &'static str;
    fn repin(&self, sig: &TransportSignals) -> Vec<RepinMove>;
}

/// The goodput-threshold re-pin rule (the default), extended with the
/// p95-latency leg:
///
/// - **Goodput leg** (PR 5, byte-identical): a path is degraded when
///   its estimate fell below `threshold_pct`% of both the per-path
///   mean and its own configured baseline.
/// - **Latency leg** (the PR 5 carried-over close): once at least two
///   paths have [`MIN_LAT_SAMPLES`] latency samples, a path whose p95
///   exceeds the ready-path mean by the inverse threshold factor
///   (`p95 × pct > mean_p95`) is degraded too.  Zero-payload streams
///   (ALL_IN_COS returns only a loss scalar) never move the goodput
///   estimates, but every response is a latency sample — this leg is
///   what lets them evacuate a slow path at all.
///
/// Slots on degraded paths evacuate round-robin over the healthy ones;
/// a displaced slot migrates back once its home is healthy again.
pub struct AnalyticRepin;

impl TransportPolicy for AnalyticRepin {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn repin(&self, sig: &TransportSignals) -> Vec<RepinMove> {
        let est: Vec<f64> = sig.paths.iter().map(|p| p.goodput).collect();
        // A path with no estimate at all (unshaped, no samples yet)
        // gives the mean no meaning — wait for data.
        if est.len() < 2 || est.iter().any(|&e| !(e.is_finite() && e > 0.0)) {
            return vec![];
        }
        let mean = est.iter().sum::<f64>() / est.len() as f64;
        let pct = sig.threshold_pct.min(100) as f64 / 100.0;
        let cutoff = mean * pct;
        let lat_ready = |i: usize| sig.paths[i].samples >= MIN_LAT_SAMPLES;
        let ready: Vec<usize> = (0..sig.paths.len()).filter(|&i| lat_ready(i)).collect();
        let mean_p95 = if ready.len() >= 2 {
            ready.iter().map(|&i| sig.paths[i].p95_ns as f64).sum::<f64>() / ready.len() as f64
        } else {
            0.0
        };
        let degraded = |i: usize| {
            let goodput_bad = est[i] < cutoff
                && (sig.paths[i].seed <= 0.0 || est[i] < sig.paths[i].seed * pct);
            let latency_bad =
                mean_p95 > 0.0 && lat_ready(i) && sig.paths[i].p95_ns as f64 * pct > mean_p95;
            goodput_bad || latency_bad
        };
        let healthy: Vec<usize> = (0..est.len()).filter(|&i| !degraded(i)).collect();
        if healthy.is_empty() {
            return vec![];
        }
        let mut moves = Vec::new();
        let mut next = 0usize;
        for (s, &cur) in sig.slot_paths.iter().enumerate() {
            let Some(&home) = sig.home_paths.get(s) else { continue };
            if cur < est.len() && degraded(cur) {
                moves.push(RepinMove {
                    slot: s,
                    path: healthy[next % healthy.len()],
                    kind: RepinKind::Evacuate,
                });
                next += 1;
            } else if cur != home && home < est.len() && !degraded(home) {
                moves.push(RepinMove {
                    slot: s,
                    path: home,
                    kind: RepinKind::MigrateBack,
                });
            }
        }
        moves
    }
}

/// Never moves a slot — the PR 4 static pinning as an explicit policy.
pub struct StaticPin;

impl TransportPolicy for StaticPin {
    fn name(&self) -> &'static str {
        "static"
    }

    fn repin(&self, _sig: &TransportSignals) -> Vec<RepinMove> {
        Vec::new()
    }
}

// ---------------------------------------------------------------------
// By-name registry (config/CLI resolve through these)
// ---------------------------------------------------------------------

pub fn split_policy(name: &str) -> Result<Box<dyn SplitPolicy>> {
    match name {
        "analytic" => Ok(Box::new(AnalyticSplit)),
        "freeze" => Ok(Box::new(FreezeSplit)),
        _ => Err(Error::Config(format!(
            "unknown split_policy '{name}' (known: analytic, freeze)"
        ))),
    }
}

pub fn batch_policy(name: &str) -> Result<Box<dyn BatchPolicy>> {
    match name {
        "analytic" => Ok(Box::new(AnalyticBatch)),
        "floor" => Ok(Box::new(FloorBatch)),
        _ => Err(Error::Config(format!(
            "unknown batch_policy '{name}' (known: analytic, floor)"
        ))),
    }
}

pub fn transport_policy(name: &str) -> Result<Box<dyn TransportPolicy>> {
    match name {
        "analytic" => Ok(Box::new(AnalyticRepin)),
        "static" => Ok(Box::new(StaticPin)),
        _ => Err(Error::Config(format!(
            "unknown transport_policy '{name}' (known: analytic, static)"
        ))),
    }
}

// ---------------------------------------------------------------------
// Decision records + trace sink
// ---------------------------------------------------------------------

/// One recorded policy invocation, serialized as a single compact JSON
/// line:
///
/// ```text
/// {"seq":3,"t_us":1754650000000000,"site":"split","policy":"analytic",
///  "signals":{...},"decision":{...}}
/// ```
///
/// `t_us` is µs since the Unix epoch — ns would overflow the exact
/// integer range of `util::json`'s f64 numbers.  Readers must tolerate
/// unknown fields (the replay harness only touches keys it knows), so
/// the schema can grow without breaking recorded traces.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionRecord {
    pub seq: u64,
    pub t_us: u64,
    /// Decision site: `"split"`, `"batch"` or `"transport"`.
    pub site: String,
    /// Name of the policy that produced the decision.
    pub policy: String,
    pub signals: Json,
    pub decision: Json,
}

impl DecisionRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seq", Json::num(self.seq as f64)),
            ("t_us", Json::num(self.t_us as f64)),
            ("site", Json::str(self.site.clone())),
            ("policy", Json::str(self.policy.clone())),
            ("signals", self.signals.clone()),
            ("decision", self.decision.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<DecisionRecord> {
        Ok(DecisionRecord {
            seq: j.get("seq")?.as_u64()?,
            t_us: j.get("t_us")?.as_u64()?,
            site: j.get("site")?.as_str()?.to_string(),
            policy: j.get("policy")?.as_str()?.to_string(),
            signals: j.get("signals")?.clone(),
            decision: j.get("decision")?.clone(),
        })
    }
}

/// Append-only JSONL writer shared by every decision site recording to
/// the same path.  Obtained through [`sink_for`]; the first opener
/// truncates, later openers join the live sink (a process-wide weak
/// registry keyed by path), so one scenario's client + scheduler +
/// planner interleave into one file with a global sequence.
pub struct TraceSink {
    path: String,
    seq: AtomicU64,
    file: Mutex<std::fs::File>,
}

fn sinks() -> &'static Mutex<BTreeMap<String, Weak<TraceSink>>> {
    static SINKS: OnceLock<Mutex<BTreeMap<String, Weak<TraceSink>>>> = OnceLock::new();
    SINKS.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Open (or join) the decision-trace sink for `path`.  An empty path
/// means tracing is off; open errors are swallowed — tracing is
/// best-effort diagnostics, never a reason to fail training.
pub fn sink_for(path: &str) -> Option<Arc<TraceSink>> {
    if path.is_empty() {
        return None;
    }
    let mut map = sinks().lock().unwrap();
    if let Some(live) = map.get(path).and_then(|w| w.upgrade()) {
        return Some(live);
    }
    let file = OpenOptions::new()
        .create(true)
        .write(true)
        .truncate(true)
        .open(path)
        .ok()?;
    let sink = Arc::new(TraceSink {
        path: path.to_string(),
        seq: AtomicU64::new(0),
        file: Mutex::new(file),
    });
    map.insert(path.to_string(), Arc::downgrade(&sink));
    Some(sink)
}

impl TraceSink {
    pub fn path(&self) -> &str {
        &self.path
    }

    /// Append one [`DecisionRecord`] line (io errors swallowed).
    pub fn record(&self, site: &str, policy: &str, signals: Json, decision: Json) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let t_us = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        let rec = DecisionRecord {
            seq,
            t_us,
            site: site.to_string(),
            policy: policy.to_string(),
            signals,
            decision,
        };
        let line = rec.to_json().to_string_compact();
        let mut f = self.file.lock().unwrap();
        let _ = writeln!(f, "{line}");
    }
}

/// Canonical decision-out JSON for a split choice.
pub fn split_decision_json(split_idx: usize) -> Json {
    Json::obj(vec![("split_idx", Json::num(split_idx as f64))])
}

/// Canonical decision-out JSON for a batch plan (or its infeasibility).
pub fn batch_decision_json(res: &Result<Solution>) -> Json {
    match res {
        Ok(sol) => {
            let assignments = sol
                .assignments
                .iter()
                .map(|a| {
                    Json::obj(vec![
                        ("id", Json::num(a.id as f64)),
                        ("batch", Json::num(a.batch as f64)),
                    ])
                })
                .collect();
            let deferred = sol.deferred.iter().map(|&d| Json::num(d as f64)).collect();
            Json::obj(vec![
                ("assignments", Json::Arr(assignments)),
                ("deferred", Json::Arr(deferred)),
                ("planned_bytes", Json::num(sol.planned_bytes as f64)),
            ])
        }
        Err(_) => Json::obj(vec![("infeasible", Json::Bool(true))]),
    }
}

/// Canonical decision-out JSON for a set of slot moves.
pub fn transport_decision_json(moves: &[RepinMove]) -> Json {
    let arr = moves
        .iter()
        .map(|m| {
            Json::obj(vec![
                ("slot", Json::num(m.slot as f64)),
                ("path", Json::num(m.path as f64)),
                (
                    "kind",
                    Json::str(match m.kind {
                        RepinKind::Evacuate => "evacuate",
                        RepinKind::MigrateBack => "migrate_back",
                    }),
                ),
            ])
        })
        .collect();
    Json::obj(vec![("moves", Json::Arr(arr))])
}

// ---------------------------------------------------------------------
// Offline replay + scoring
// ---------------------------------------------------------------------

/// The three policies a replay evaluates as one unit.
pub struct PolicySet {
    pub split: Box<dyn SplitPolicy>,
    pub batch: Box<dyn BatchPolicy>,
    pub transport: Box<dyn TransportPolicy>,
}

impl PolicySet {
    /// The byte-identical defaults.
    pub fn analytic() -> PolicySet {
        PolicySet {
            split: Box::new(AnalyticSplit),
            batch: Box::new(AnalyticBatch),
            transport: Box::new(AnalyticRepin),
        }
    }
}

/// Per-site replay score.
#[derive(Debug, Clone, Default)]
pub struct SiteScore {
    pub records: usize,
    /// Records where the candidate reproduced the recorded decision.
    pub matched: usize,
    /// Summed |predicted cost delta| between candidate and recorded
    /// decisions.  Units per site: seconds of per-iteration transfer
    /// (split, via [`theory::t_data_bytes`]), planned bytes (batch),
    /// differently-routed slots (transport).
    pub delta_sum: f64,
}

impl SiteScore {
    pub fn match_pct(&self) -> f64 {
        if self.records == 0 {
            100.0
        } else {
            self.matched as f64 * 100.0 / self.records as f64
        }
    }

    pub fn mean_delta(&self) -> f64 {
        if self.records == 0 {
            0.0
        } else {
            self.delta_sum / self.records as f64
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct EvalReport {
    /// Scores keyed by site name (`split` / `batch` / `transport`).
    pub sites: BTreeMap<String, SiteScore>,
    /// Records whose site this harness does not know (tolerated for
    /// forward compatibility, counted so they are not silent).
    pub skipped: usize,
}

impl EvalReport {
    pub fn records(&self) -> usize {
        self.sites.values().map(|s| s.records).sum()
    }

    pub fn matched(&self) -> usize {
        self.sites.values().map(|s| s.matched).sum()
    }

    pub fn match_pct(&self) -> f64 {
        let n = self.records();
        if n == 0 {
            100.0
        } else {
            self.matched() as f64 * 100.0 / n as f64
        }
    }
}

fn parse_recorded_split(decision: &Json) -> Result<usize> {
    decision.get("split_idx")?.as_usize()
}

/// `None` = recorded as infeasible.
type BatchOutcome = Option<(Vec<Assignment>, Vec<u64>, u64)>;

fn parse_recorded_batch(decision: &Json) -> Result<BatchOutcome> {
    if let Some(Json::Bool(true)) = decision.opt("infeasible") {
        return Ok(None);
    }
    let assignments = decision
        .get("assignments")?
        .as_arr()?
        .iter()
        .map(|a| {
            Ok(Assignment {
                id: a.get("id")?.as_u64()?,
                batch: a.get("batch")?.as_usize()?,
            })
        })
        .collect::<Result<Vec<Assignment>>>()?;
    let deferred = decision
        .get("deferred")?
        .as_arr()?
        .iter()
        .map(|d| d.as_u64())
        .collect::<Result<Vec<u64>>>()?;
    let planned = decision.get("planned_bytes")?.as_u64()?;
    Ok(Some((assignments, deferred, planned)))
}

fn batch_outcome(res: &Result<Solution>) -> BatchOutcome {
    res.as_ref()
        .ok()
        .map(|sol| (sol.assignments.clone(), sol.deferred.clone(), sol.planned_bytes))
}

fn parse_recorded_moves(decision: &Json) -> Result<Vec<RepinMove>> {
    decision
        .get("moves")?
        .as_arr()?
        .iter()
        .map(|m| {
            let kind = match m.get("kind")?.as_str()? {
                "evacuate" => RepinKind::Evacuate,
                "migrate_back" => RepinKind::MigrateBack,
                other => {
                    return Err(Error::Json(format!("unknown repin kind '{other}'")));
                }
            };
            Ok(RepinMove {
                slot: m.get("slot")?.as_usize()?,
                path: m.get("path")?.as_usize()?,
                kind,
            })
        })
        .collect()
}

fn apply_moves(slots: &[usize], moves: &[RepinMove]) -> Vec<usize> {
    let mut out = slots.to_vec();
    for m in moves {
        if m.slot < out.len() {
            out[m.slot] = m.path;
        }
    }
    out
}

/// Replay every record in `text` (one JSON object per line, blank
/// lines skipped) against `policies` and score per site.  A malformed
/// line is an error — a trace that cannot be parsed should not be
/// silently scored — but unknown *fields* and unknown *sites* are
/// tolerated for forward compatibility.
pub fn eval_records(text: &str, policies: &PolicySet) -> Result<EvalReport> {
    let mut report = EvalReport::default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| Error::Json(format!("trace line {}: {e}", lineno + 1)))?;
        let rec = DecisionRecord::from_json(&j)
            .map_err(|e| Error::Json(format!("trace line {}: {e}", lineno + 1)))?;
        match rec.site.as_str() {
            "split" => {
                let sig = SplitSignals::from_json(&rec.signals)?;
                let recorded = parse_recorded_split(&rec.decision)?;
                let cand = policies.split.choose(&sig);
                let s = report.sites.entry("split".into()).or_default();
                s.records += 1;
                if cand == recorded {
                    s.matched += 1;
                }
                if let Some(bw) = sig.bandwidth {
                    let per_iter = |idx: usize| {
                        let out =
                            sig.out_bytes.get(idx.saturating_sub(1)).copied().unwrap_or(0);
                        out as f64 * sig.train_batch as f64
                    };
                    s.delta_sum += (theory::t_data_bytes(per_iter(cand), bw as f64)
                        - theory::t_data_bytes(per_iter(recorded), bw as f64))
                    .abs();
                }
            }
            "batch" => {
                let sig = BatchSignals::from_json(&rec.signals)?;
                let recorded = parse_recorded_batch(&rec.decision)?;
                let cand = batch_outcome(&policies.batch.plan(&sig));
                let s = report.sites.entry("batch".into()).or_default();
                s.records += 1;
                if cand == recorded {
                    s.matched += 1;
                }
                if let (Some((_, _, a)), Some((_, _, b))) = (&cand, &recorded) {
                    s.delta_sum += (*a as f64 - *b as f64).abs();
                }
            }
            "transport" => {
                let sig = TransportSignals::from_json(&rec.signals)?;
                let recorded = parse_recorded_moves(&rec.decision)?;
                let cand = policies.transport.repin(&sig);
                let s = report.sites.entry("transport".into()).or_default();
                s.records += 1;
                if cand == recorded {
                    s.matched += 1;
                }
                let a = apply_moves(&sig.slot_paths, &cand);
                let b = apply_moves(&sig.slot_paths, &recorded);
                s.delta_sum +=
                    a.iter().zip(&b).filter(|(x, y)| x != y).count() as f64;
            }
            _ => report.skipped += 1,
        }
    }
    Ok(report)
}

/// [`eval_records`] over a trace file.
pub fn eval_trace(path: &str, policies: &PolicySet) -> Result<EvalReport> {
    let text = std::fs::read_to_string(path)?;
    eval_records(&text, policies)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn split_sig(bandwidth: Option<u64>) -> SplitSignals {
        SplitSignals {
            input_bytes: 1000,
            freeze_idx: 5,
            out_bytes: vec![1500, 800, 1200, 200, 100],
            bandwidth,
            window_secs: 1.0,
            train_batch: 10,
            pipeline_depth: 2,
        }
    }

    fn batch_sig(budget: u64) -> BatchSignals {
        BatchSignals {
            requests: vec![
                BatchRequest {
                    id: 1,
                    data_bytes_per_sample: 100,
                    model_bytes: 1000,
                    b_max: 80,
                },
                BatchRequest {
                    id: 2,
                    data_bytes_per_sample: 50,
                    model_bytes: 500,
                    b_max: 100,
                },
            ],
            budget,
            b_min: 20,
            step: 20,
        }
    }

    fn transport_sig(goodputs: &[f64], p95s: &[u64], samples: u64) -> TransportSignals {
        TransportSignals {
            paths: goodputs
                .iter()
                .zip(p95s)
                .enumerate()
                .map(|(i, (&g, &p))| PathSnapshot {
                    path: i,
                    goodput: g,
                    seed: g.max(1.0),
                    p95_ns: p,
                    samples,
                })
                .collect(),
            slot_paths: (0..goodputs.len()).collect(),
            home_paths: (0..goodputs.len()).collect(),
            threshold_pct: 60,
        }
    }

    #[test]
    fn signal_jsons_round_trip() {
        for bw in [None, Some(3000u64)] {
            let sig = split_sig(bw);
            assert_eq!(SplitSignals::from_json(&sig.to_json()).unwrap(), sig);
        }
        let b = batch_sig(6000);
        let back = BatchSignals::from_json(&b.to_json()).unwrap();
        assert_eq!(back.budget, 6000);
        assert_eq!(back.requests.len(), 2);
        assert_eq!(back.requests[1].id, 2);
        let t = transport_sig(&[100.0, 200.0], &[10, 20], 9);
        assert_eq!(TransportSignals::from_json(&t.to_json()).unwrap(), t);
    }

    #[test]
    fn analytic_split_matches_algorithm_one() {
        // Same fixture as split/mod.rs: scarce bandwidth walks toward
        // the freeze index.
        assert_eq!(AnalyticSplit.choose(&split_sig(Some(1_000_000_000))), 2);
        assert_eq!(AnalyticSplit.choose(&split_sig(Some(3000))), 4);
        assert_eq!(AnalyticSplit.choose(&split_sig(Some(600))), 5);
        assert_eq!(AnalyticSplit.choose(&split_sig(None)), 2);
        assert_eq!(FreezeSplit.choose(&split_sig(None)), 5);
    }

    #[test]
    fn floor_batch_grants_floors_and_drops_tail() {
        let sol = FloorBatch.plan(&batch_sig(1 << 30)).unwrap();
        assert_eq!(sol.assignments.len(), 2);
        assert!(sol.assignments.iter().all(|a| a.batch == 20));
        // Budget fits request 1's floor (3000) but not both (4500).
        let sol = FloorBatch.plan(&batch_sig(3500)).unwrap();
        assert_eq!(sol.assignments.len(), 1);
        assert_eq!(sol.deferred, vec![2]);
        // Even one floor cannot fit.
        let err = FloorBatch.plan(&batch_sig(100)).unwrap_err();
        assert!(matches!(err, Error::Infeasible(_)));
    }

    #[test]
    fn analytic_repin_goodput_leg_matches_scheduler_rule() {
        // Path 0 collapsed to 1/20th: evacuate its slot to path 1.
        let mut sig = transport_sig(&[50_000.0, 1_000_000.0], &[0, 0], 0);
        sig.paths[0].seed = 1_000_000.0;
        sig.paths[1].seed = 1_000_000.0;
        let moves = AnalyticRepin.repin(&sig);
        assert_eq!(
            moves,
            vec![RepinMove {
                slot: 0,
                path: 1,
                kind: RepinKind::Evacuate
            }]
        );
        // A displaced slot migrates back once home is healthy.
        let mut back = transport_sig(&[1_000_000.0, 1_000_000.0], &[0, 0], 0);
        back.slot_paths = vec![1, 1];
        let moves = AnalyticRepin.repin(&back);
        assert_eq!(
            moves,
            vec![RepinMove {
                slot: 0,
                path: 0,
                kind: RepinKind::MigrateBack
            }]
        );
        // Heterogeneous rates: running at its own seed is healthy.
        let mut het = transport_sig(&[2_000_000.0, 8_000_000.0], &[0, 0], 0);
        het.paths[0].seed = 2_000_000.0;
        het.paths[1].seed = 8_000_000.0;
        assert!(AnalyticRepin.repin(&het).is_empty());
    }

    #[test]
    fn analytic_repin_latency_leg_catches_zero_payload_streams() {
        // Equal goodputs (seeded, never moved by zero-byte samples):
        // the goodput leg sees nothing.  Path 0's p95 is 6x path 1's,
        // which at 60% exceeds the inverse-threshold bound.
        let sig = transport_sig(
            &[100_000.0, 100_000.0],
            &[600_000_000, 100_000_000],
            MIN_LAT_SAMPLES,
        );
        let moves = AnalyticRepin.repin(&sig);
        assert_eq!(
            moves,
            vec![RepinMove {
                slot: 0,
                path: 1,
                kind: RepinKind::Evacuate
            }]
        );
        // Below the sample floor the latency leg stays inert.
        let cold = transport_sig(
            &[100_000.0, 100_000.0],
            &[600_000_000, 100_000_000],
            MIN_LAT_SAMPLES - 1,
        );
        assert!(AnalyticRepin.repin(&cold).is_empty());
        // Uniform latencies never trip the leg.
        let uniform = transport_sig(&[100_000.0, 100_000.0], &[5_000_000, 5_000_000], 50);
        assert!(AnalyticRepin.repin(&uniform).is_empty());
        assert!(StaticPin.repin(&sig).is_empty());
    }

    #[test]
    fn record_round_trips_and_tolerates_unknown_fields() {
        let rec = DecisionRecord {
            seq: 7,
            t_us: 1_754_650_000_000_000,
            site: "split".into(),
            policy: "analytic".into(),
            signals: split_sig(Some(3000)).to_json(),
            decision: split_decision_json(4),
        };
        let mut j = rec.to_json();
        assert_eq!(DecisionRecord::from_json(&j).unwrap(), rec);
        // Forward compat: an extra top-level field parses fine.
        if let Json::Obj(m) = &mut j {
            m.insert("future_field".into(), Json::str("ignored"));
        }
        assert_eq!(DecisionRecord::from_json(&j).unwrap(), rec);
    }

    fn trace_text() -> String {
        let mut lines = Vec::new();
        for (seq, bw) in [Some(1_000_000_000u64), Some(3000), Some(600), None]
            .iter()
            .enumerate()
        {
            let sig = split_sig(*bw);
            let rec = DecisionRecord {
                seq: seq as u64,
                t_us: 1,
                site: "split".into(),
                policy: "analytic".into(),
                decision: split_decision_json(AnalyticSplit.choose(&sig)),
                signals: sig.to_json(),
            };
            lines.push(rec.to_json().to_string_compact());
        }
        for (seq, budget) in [1u64 << 30, 6000, 100].iter().enumerate() {
            let sig = batch_sig(*budget);
            let rec = DecisionRecord {
                seq: seq as u64,
                t_us: 2,
                site: "batch".into(),
                policy: "analytic".into(),
                decision: batch_decision_json(&AnalyticBatch.plan(&sig)),
                signals: sig.to_json(),
            };
            lines.push(rec.to_json().to_string_compact());
        }
        let mut tsig = transport_sig(&[50_000.0, 1_000_000.0], &[0, 0], 0);
        tsig.paths[0].seed = 1_000_000.0;
        let rec = DecisionRecord {
            seq: 0,
            t_us: 3,
            site: "transport".into(),
            policy: "analytic".into(),
            decision: transport_decision_json(&AnalyticRepin.repin(&tsig)),
            signals: tsig.to_json(),
        };
        lines.push(rec.to_json().to_string_compact());
        lines.join("\n")
    }

    #[test]
    fn replaying_defaults_scores_full_match() {
        let report = eval_records(&trace_text(), &PolicySet::analytic()).unwrap();
        assert_eq!(report.records(), 8);
        assert_eq!(report.matched(), 8);
        assert_eq!(report.match_pct(), 100.0);
        assert_eq!(report.skipped, 0);
        for s in report.sites.values() {
            assert_eq!(s.mean_delta(), 0.0);
        }
    }

    #[test]
    fn replaying_a_different_policy_scores_mismatches() {
        let policies = PolicySet {
            split: Box::new(FreezeSplit),
            batch: Box::new(FloorBatch),
            transport: Box::new(StaticPin),
        };
        let report = eval_records(&trace_text(), &policies).unwrap();
        assert!(report.match_pct() < 100.0);
        let split = &report.sites["split"];
        // FreezeSplit agrees only where Algorithm 1 already fell back
        // to the freeze index (the 600 B/s record).
        assert_eq!(split.matched, 1);
        assert!(split.delta_sum > 0.0, "cost-model delta must be scored");
        let transport = &report.sites["transport"];
        assert_eq!(transport.matched, 0);
        assert_eq!(transport.delta_sum, 1.0, "one slot routed differently");
    }

    #[test]
    fn eval_tolerates_unknown_sites_and_blank_lines() {
        let extra = format!(
            "{}\n\n{}\n",
            trace_text(),
            Json::obj(vec![
                ("seq", Json::num(99.0)),
                ("t_us", Json::num(1.0)),
                ("site", Json::str("admission")),
                ("policy", Json::str("learned")),
                ("signals", Json::obj(vec![])),
                ("decision", Json::obj(vec![])),
            ])
            .to_string_compact()
        );
        let report = eval_records(&extra, &PolicySet::analytic()).unwrap();
        assert_eq!(report.match_pct(), 100.0);
        assert_eq!(report.skipped, 1);
    }

    #[test]
    fn eval_rejects_malformed_lines() {
        assert!(eval_records("{not json", &PolicySet::analytic()).is_err());
        let noise = Json::obj(vec![("seq", Json::num(1.0))]).to_string_compact();
        assert!(eval_records(&noise, &PolicySet::analytic()).is_err());
    }

    #[test]
    fn by_name_registry_rejects_unknown_policies() {
        assert!(split_policy("analytic").is_ok());
        assert!(split_policy("freeze").is_ok());
        assert!(batch_policy("floor").is_ok());
        assert!(transport_policy("static").is_ok());
        for bad in [
            split_policy("nope").err(),
            batch_policy("nope").err(),
            transport_policy("nope").err(),
        ] {
            assert!(matches!(bad, Some(Error::Config(_))));
        }
    }

    #[test]
    fn trace_sink_interleaves_sites_with_one_sequence() {
        let path = std::env::temp_dir().join(format!(
            "hapi_policy_sink_test_{}.jsonl",
            std::process::id()
        ));
        let path_str = path.to_string_lossy().to_string();
        {
            let a = sink_for(&path_str).unwrap();
            let b = sink_for(&path_str).unwrap();
            assert!(Arc::ptr_eq(&a, &b), "same path must share one sink");
            a.record("split", "analytic", split_sig(None).to_json(), split_decision_json(2));
            b.record(
                "batch",
                "analytic",
                batch_sig(1 << 30).to_json(),
                batch_decision_json(&AnalyticBatch.plan(&batch_sig(1 << 30))),
            );
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let seqs: Vec<u64> = text
            .lines()
            .map(|l| {
                DecisionRecord::from_json(&Json::parse(l).unwrap())
                    .unwrap()
                    .seq
            })
            .collect();
        assert_eq!(seqs, vec![0, 1]);
        let report = eval_records(&text, &PolicySet::analytic()).unwrap();
        assert_eq!(report.match_pct(), 100.0);
        let _ = std::fs::remove_file(&path);
        assert!(sink_for("").is_none(), "empty path = tracing off");
    }
}
