//! Canonical metric names — the single definition every producer,
//! test, bench and conservation check shares.
//!
//! Names follow the `component.name` convention (`hapi-analyze`'s
//! metric-name pass enforces it): the first segment is the owning
//! component (`hapi`, `ba`, `pipeline`, `cos`), the rest is
//! `lower_snake` with an explicit unit suffix where one applies
//! (`_ns`, `_bytes`, `_pct_x100`).  Per-entity families (one
//! instrument per lane / connection / path) are constructed through
//! the functions at the bottom so the id placement is uniform and the
//! eviction prefixes ([`crate::metrics::Registry::evict_prefix`])
//! cannot drift from the names they are meant to match.
//!
//! Adding a metric: add the const (or family fn) here, emit it via
//! `names::…` at the producer, and document it in the metric table in
//! `rust/src/README.md` — `hapi-analyze` fails CI on producers that
//! bypass this module, names that never get produced, and names
//! missing from the README table.

// ---------------------------------------------------------------- hapi.*
// Server-side request accounting (server/mod.rs).

pub const HAPI_REQUESTS: &str = "hapi.requests";
pub const HAPI_REQUEST_NS: &str = "hapi.request_ns";
pub const HAPI_DEVICE_USED_MAX: &str = "hapi.device_used_max";
pub const HAPI_OOM: &str = "hapi.oom";

// ------------------------------------------------------------------ ba.*
// Batch-adaptation planner (server/planner.rs).

pub const BA_REQUESTS: &str = "ba.requests";
pub const BA_GRANTS: &str = "ba.grants";
pub const BA_RUNS: &str = "ba.runs";
pub const BA_SOLVE_NS: &str = "ba.solve_ns";
pub const BA_REDUCTION_PCT_X100: &str = "ba.reduction_pct_x100";
pub const BA_BURST_WIDTH: &str = "ba.burst_width";
pub const BA_BURST_CLAMPED: &str = "ba.burst_clamped";
pub const BA_GATHER_WINDOW_NS: &str = "ba.gather_window_ns";
pub const BA_LANES_ACTIVE: &str = "ba.lanes_active";
pub const BA_POLICY_DECISIONS: &str = "ba.policy_decisions";
pub const BA_REJECTS: &str = "ba.rejects";
pub const BA_REAPED: &str = "ba.reaped";
pub const BA_TIME_TO_GRANT_NS: &str = "ba.time_to_grant_ns";

// ------------------------------------------------------------ pipeline.*
// Client-side prefetch pipeline, sharded fetch engine and transport
// scheduler (client/pipeline.rs, client/transport.rs, client/mod.rs).

pub const PIPELINE_DEPTH: &str = "pipeline.depth";
pub const PIPELINE_FANOUT: &str = "pipeline.fanout";
pub const PIPELINE_ITERATIONS: &str = "pipeline.iterations";
pub const PIPELINE_BYTES: &str = "pipeline.bytes";
pub const PIPELINE_FETCH_NS: &str = "pipeline.fetch_ns";
pub const PIPELINE_COMPUTE_NS: &str = "pipeline.compute_ns";
pub const PIPELINE_STALL_NS: &str = "pipeline.stall_ns";
pub const PIPELINE_INFLIGHT_MAX: &str = "pipeline.inflight_max";
pub const PIPELINE_SHARD_FETCH_NS: &str = "pipeline.shard_fetch_ns";
pub const PIPELINE_SHARD_RETRIES: &str = "pipeline.shard_retries";
pub const PIPELINE_SPLIT_REDECISIONS: &str = "pipeline.split_redecisions";
pub const PIPELINE_HEDGES: &str = "pipeline.hedges";
pub const PIPELINE_HEDGE_WINS: &str = "pipeline.hedge_wins";
pub const PIPELINE_HEDGE_BYTES: &str = "pipeline.hedge_bytes";
pub const PIPELINE_HEDGE_WASTED_BYTES: &str = "pipeline.hedge_wasted_bytes";
pub const PIPELINE_REPINS: &str = "pipeline.repins";
pub const PIPELINE_REPINS_BACK: &str = "pipeline.repins_back";
pub const PIPELINE_PROBES: &str = "pipeline.probes";
pub const PIPELINE_POLICY_DECISIONS: &str = "pipeline.policy_decisions";
pub const PIPELINE_ADMIT_RETRIES: &str = "pipeline.admit_retries";
pub const PIPELINE_TIMEOUTS: &str = "pipeline.timeouts";
pub const PIPELINE_INTEGRITY_FAIL: &str = "pipeline.integrity_fail";
pub const PIPELINE_BREAKER_OPEN: &str = "pipeline.breaker_open";
pub const PIPELINE_BREAKER_TRIPS: &str = "pipeline.breaker_trips";

// ----------------------------------------------------------------- cos.*
// Storage tier: object store + proxy front ends (cos/).

pub const COS_GET: &str = "cos.get";
pub const COS_GET_BYTES: &str = "cos.get_bytes";
pub const COS_PUT: &str = "cos.put";
pub const COS_PUT_BYTES: &str = "cos.put_bytes";
pub const COS_POST: &str = "cos.post";
pub const COS_POST_LATENCY_NS: &str = "cos.post_latency_ns";
pub const COS_INTEGRITY_FAIL: &str = "cos.integrity_fail";

// ------------------------------------------------------- per-entity families

/// `ba.lane.<client>.gather_window_ns` — per-lane gather window.
pub fn lane_gather_window_ns(client: impl std::fmt::Display) -> String {
    format!("ba.lane.{client}.gather_window_ns")
}

/// `ba.lane.<client>.` — eviction prefix covering one lane's family.
pub fn lane_prefix(client: impl std::fmt::Display) -> String {
    format!("ba.lane.{client}.")
}

/// `ba.shard<i>.lanes` — live lanes held by planner shard `i`.
pub fn shard_lanes(i: impl std::fmt::Display) -> String {
    format!("ba.shard{i}.lanes")
}

/// `pipeline.conn<c>.bytes` — payload bytes served by fetch slot `c`.
pub fn conn_bytes(c: impl std::fmt::Display) -> String {
    format!("pipeline.conn{c}.bytes")
}

/// `pipeline.conn<c>.fetch_ns` — per-slot fetch latency.
pub fn conn_fetch_ns(c: impl std::fmt::Display) -> String {
    format!("pipeline.conn{c}.fetch_ns")
}

/// `pipeline.path<p>.bytes` — payload bytes carried by network path `p`.
pub fn path_bytes(p: impl std::fmt::Display) -> String {
    format!("pipeline.path{p}.bytes")
}

/// `pipeline.path<p>.fetch_ns` — per-path fetch latency.
pub fn path_fetch_ns(p: impl std::fmt::Display) -> String {
    format!("pipeline.path{p}.fetch_ns")
}

/// `cos.path<id>.requests` — requests served by the proxy on path `id`.
pub fn cos_path_requests(id: impl std::fmt::Display) -> String {
    format!("cos.path{id}.requests")
}
