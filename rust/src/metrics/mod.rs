//! Metrics substrate: counters, gauges, histograms, and report tables.
//!
//! Every experiment binary reports through this module so the paper-style
//! tables (EXPERIMENTS.md) come out of one formatter.  Histograms use
//! fixed-precision log buckets — enough for p50/p95/p99 on latencies
//! spanning µs to minutes.

pub mod histogram;
pub mod names;
pub mod registry;
pub mod table;

pub use histogram::Histogram;
pub use registry::{Counter, Gauge, Registry};
pub use table::Table;
