//! Named counters/gauges/histograms with a JSON snapshot.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::histogram::Histogram;
use crate::util::json::Json;

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1)
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Process-wide registry; cheap to clone, interior-mutable.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Mutex<Inner>>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.inner
            .lock()
            .unwrap()
            .counters
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.inner
            .lock()
            .unwrap()
            .gauges
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// Drop every counter/gauge/histogram whose name starts with
    /// `prefix`; returns how many instruments were evicted.  This is
    /// the cardinality relief valve for per-entity metric families
    /// (e.g. the planner's `ba.lane.<id>.*`): without it a long-lived
    /// process accumulates one instrument per entity ever seen.
    /// Handles already held by callers keep recording into the detached
    /// instrument; the registry re-creates a fresh one on next lookup.
    pub fn evict_prefix(&self, prefix: &str) -> usize {
        let mut g = self.inner.lock().unwrap();
        let before =
            g.counters.len() + g.gauges.len() + g.histograms.len();
        g.counters.retain(|k, _| !k.starts_with(prefix));
        g.gauges.retain(|k, _| !k.starts_with(prefix));
        g.histograms.retain(|k, _| !k.starts_with(prefix));
        before - (g.counters.len() + g.gauges.len() + g.histograms.len())
    }

    /// JSON snapshot: counters/gauges verbatim, histograms as summary.
    pub fn snapshot(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let counters = g
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(v.get() as f64)))
            .collect();
        let gauges = g
            .gauges
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(v.get() as f64)))
            .collect();
        let hists = g
            .histograms
            .iter()
            .map(|(k, h)| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("count", Json::num(h.count() as f64)),
                        ("mean", Json::num(h.mean())),
                        ("p50", Json::num(h.p50() as f64)),
                        ("p95", Json::num(h.p95() as f64)),
                        ("p99", Json::num(h.p99() as f64)),
                        ("max", Json::num(h.max() as f64)),
                    ]),
                )
            })
            .collect();
        Json::Obj(
            [
                ("counters".to_string(), Json::Obj(counters)),
                ("gauges".to_string(), Json::Obj(gauges)),
                ("histograms".to_string(), Json::Obj(hists)),
            ]
            .into_iter()
            .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_name_same_instance() {
        let r = Registry::new();
        r.counter("x").inc();
        r.counter("x").add(2);
        assert_eq!(r.counter("x").get(), 3);
        r.gauge("g").set(-5);
        assert_eq!(r.gauge("g").get(), -5);
    }

    #[test]
    fn evict_prefix_drops_matching_instruments_only() {
        let r = Registry::new();
        r.counter("ba.lane.1.hits").add(3);
        r.histogram("ba.lane.1.gather_window_ns").record(9);
        r.gauge("ba.lane.1.depth").set(2);
        r.histogram("ba.lane.12.gather_window_ns").record(7);
        r.counter("ba.requests").add(1);
        assert_eq!(r.evict_prefix("ba.lane.1."), 3);
        let snap = r.snapshot();
        let hists = snap.get("histograms").unwrap().as_obj().unwrap();
        assert!(!hists.contains_key("ba.lane.1.gather_window_ns"));
        // Prefix match is exact: lane 12 and non-lane metrics survive.
        assert!(hists.contains_key("ba.lane.12.gather_window_ns"));
        assert_eq!(r.counter("ba.requests").get(), 1);
        // A fresh lookup re-creates an empty instrument.
        assert_eq!(
            r.histogram("ba.lane.1.gather_window_ns").count(),
            0
        );
    }

    #[test]
    fn snapshot_shape() {
        let r = Registry::new();
        r.counter("reqs").add(7);
        r.histogram("lat").record(100);
        let snap = r.snapshot();
        assert_eq!(
            snap.get("counters").unwrap().get("reqs").unwrap().as_u64().unwrap(),
            7
        );
        let lat = snap.get("histograms").unwrap().get("lat").unwrap();
        assert_eq!(lat.get("count").unwrap().as_u64().unwrap(), 1);
    }
}
