//! Log-bucketed histogram for latency/size distributions.
//!
//! Buckets are powers of `2^(1/8)` (±~9% relative error), covering 1 ns to
//! ~10 minutes when recording nanoseconds.  Lock-free recording via atomic
//! bucket counters; quantile queries take a snapshot.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

const BUCKETS: usize = 512;
const SUB_BITS: u32 = 3; // 8 sub-buckets per octave

pub struct Histogram {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    /// Lowest/highest occupied bucket: quantile scans only this range
    /// instead of all 512 buckets.  Nanosecond latencies land around
    /// bucket ~240, so an unbounded scan walks hundreds of empty
    /// buckets per call — and these are queried per snapshot row.
    lo_bucket: AtomicUsize,
    hi_bucket: AtomicUsize,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

fn bucket_of(v: u64) -> usize {
    if v == 0 {
        return 0;
    }
    let log2 = 63 - v.leading_zeros();
    let sub = if log2 >= SUB_BITS {
        ((v >> (log2 - SUB_BITS)) & ((1 << SUB_BITS) - 1)) as usize
    } else {
        0
    };
    (((log2 as usize) << SUB_BITS) + sub + 1).min(BUCKETS - 1)
}

fn bucket_floor(b: usize) -> u64 {
    if b == 0 {
        return 0;
    }
    let b = b - 1;
    let log2 = (b >> SUB_BITS) as u32;
    let sub = (b & ((1 << SUB_BITS) - 1)) as u64;
    if log2 >= SUB_BITS {
        (1u64 << log2) + (sub << (log2 - SUB_BITS))
    } else {
        1u64 << log2
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            lo_bucket: AtomicUsize::new(BUCKETS),
            hi_bucket: AtomicUsize::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        let b = bucket_of(v);
        self.counts[b].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.lo_bucket.fetch_min(b, Ordering::Relaxed);
        self.hi_bucket.fetch_max(b, Ordering::Relaxed);
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Quantile in `[0, 1]`; returns the lower edge of the matching bucket.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        // Scan only the occupied bucket range and stop as soon as the
        // target rank is covered — a single-bucket population (e.g. one
        // recorded value) answers any quantile after one bucket.
        let lo = self.lo_bucket.load(Ordering::Relaxed);
        let hi = self.hi_bucket.load(Ordering::Relaxed).min(BUCKETS - 1);
        let mut seen = 0;
        for (b, c) in
            self.counts.iter().enumerate().take(hi + 1).skip(lo)
        {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_floor(b);
            }
        }
        self.max()
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let p50 = h.p50() as f64;
        assert!((4300.0..=5100.0).contains(&p50), "p50 {p50}");
        let p99 = h.p99() as f64;
        assert!((8900.0..=10000.0).contains(&p99), "p99 {p99}");
        assert_eq!(h.max(), 10_000);
        assert!((h.mean() - 5000.5).abs() < 1.0);
    }

    #[test]
    fn single_value_quantiles_agree() {
        // One sample occupies one bucket: every quantile must resolve
        // to it (and via the bounded scan, after visiting exactly that
        // bucket — not all 512).
        let h = Histogram::new();
        h.record(1_000_000);
        assert_eq!(h.p50(), h.p99());
        assert_eq!(h.quantile(0.0), h.quantile(1.0));
        assert!(h.p50() <= 1_000_000 && h.p50() > 900_000);
    }

    #[test]
    fn bucket_monotone() {
        let mut last = 0;
        for v in [0u64, 1, 2, 3, 7, 8, 100, 1_000, 1 << 20, 1 << 40] {
            let b = bucket_of(v);
            assert!(b >= last, "v={v} b={b} last={last}");
            last = b;
            assert!(bucket_floor(b) <= v.max(1));
        }
    }

    #[test]
    fn concurrent_recording() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for v in 0..1000 {
                        h.record(v);
                    }
                })
            })
            .collect();
        for t in hs {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }
}
