//! Paper-style ASCII tables + CSV emission for experiment reports.

use std::fmt::Write as _;

#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "## {}", self.title);
        }
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            out.push_str(&s);
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with sensible precision for reports.
pub fn fnum(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Fig X", &["model", "time (s)"]);
        t.row(vec!["alexnet".into(), "1.5".into()]);
        t.row(vec!["vgg19".into(), "120".into()]);
        let r = t.render();
        assert!(r.contains("## Fig X"));
        assert!(r.contains("| alexnet | 1.5      |"));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        Table::new("", &["a", "b"]).row(vec!["only-one".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(3.14159), "3.14");
        assert_eq!(fnum(42.19), "42.2");
        assert_eq!(fnum(1234.5), "1234");
    }
}
