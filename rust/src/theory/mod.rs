//! §4 — the analytic cost model (Eqs. 1–3).
//!
//! Predicts one epoch's training time as
//! `C_COS + C_Client + T_Data` under the paper's four assumptions
//! (time-sliced COS GPU, linear PCIe transfers, uniform per-layer cost,
//! perfect intra-batch parallelism).  Used by the §7.3 analysis (dynamic
//! vs static-freeze split) and by tests that check the splitter's choices
//! are consistent with the model's ordering.

use crate::profiler::AppProfile;

/// Constants of Eqs. 1–2.  Defaults are in arbitrary-but-consistent time
/// units; only *orderings and ratios* of predictions are meaningful,
/// which is all §4 uses them for.
#[derive(Debug, Clone)]
pub struct CostConstants {
    /// C11: COS DRAM↔GPU transfer seconds per byte.
    pub c11: f64,
    /// C12: COS seconds per processed unit (per request).
    pub c12: f64,
    /// C21: client DRAM↔GPU transfer seconds per byte.
    pub c21: f64,
    /// C22: client seconds per processed unit.
    pub c22: f64,
}

impl Default for CostConstants {
    fn default() -> Self {
        CostConstants {
            c11: 1e-9,
            c12: 1e-3,
            c21: 1e-9,
            c22: 1e-3,
        }
    }
}

#[derive(Debug, Clone)]
pub struct EpochPrediction {
    pub c_cos: f64,
    pub c_client: f64,
    pub t_data: f64,
}

impl EpochPrediction {
    pub fn total(&self) -> f64 {
        self.c_cos + self.c_client + self.t_data
    }
}

/// Eq. 1: COS computation time for one epoch.
///
/// `concurrent` is |R(t)| (time-sliced sharing), `dataset` is |D|.
pub fn c_cos(
    app: &AppProfile,
    k: &CostConstants,
    split: usize,
    cos_batch: usize,
    dataset: usize,
    concurrent: usize,
) -> f64 {
    let l0 = app.input_bytes() as f64;
    let l_split = app.out_bytes(split) as f64;
    let batches = (dataset as f64 / cos_batch as f64).ceil();
    concurrent as f64
        * batches
        * (k.c11 * cos_batch as f64 * (l0 + l_split) + k.c12 * split as f64)
}

/// Eq. 2: client computation time for one epoch.
pub fn c_client(
    app: &AppProfile,
    k: &CostConstants,
    split: usize,
    train_batch: usize,
    dataset: usize,
) -> f64 {
    let l_split = app.out_bytes(split) as f64;
    let l_client = (app.num_units() - split) as f64;
    let batches = (dataset as f64 / train_batch as f64).ceil();
    batches * (k.c21 * train_batch as f64 * l_split + k.c22 * l_client)
}

/// T_Data on raw signals: seconds to move `bytes` at `bandwidth`
/// bytes/sec.  The policy-replay scorer uses this directly (it has no
/// `AppProfile`, only recorded byte counts).
pub fn t_data_bytes(bytes: f64, bandwidth: f64) -> f64 {
    bytes / bandwidth
}

/// T_Data: network transfer time for one epoch.
pub fn t_data(app: &AppProfile, split: usize, dataset: usize, bandwidth: f64) -> f64 {
    t_data_bytes(app.out_bytes(split) as f64 * dataset as f64, bandwidth)
}

/// Full Eq. 3 objective for a candidate split.
#[allow(clippy::too_many_arguments)]
pub fn predict(
    app: &AppProfile,
    k: &CostConstants,
    split: usize,
    cos_batch: usize,
    train_batch: usize,
    dataset: usize,
    concurrent: usize,
    bandwidth: f64,
) -> EpochPrediction {
    EpochPrediction {
        c_cos: c_cos(app, k, split, cos_batch, dataset, concurrent),
        c_client: c_client(app, k, split, train_batch, dataset),
        t_data: t_data(app, split, dataset, bandwidth),
    }
}

/// §4's headline observations, as checkable predicates.
pub mod observations {
    use super::*;

    /// Obs 2: pushing more units down costs more COS time when shared.
    pub fn deeper_split_costs_more_cos(
        app: &AppProfile,
        k: &CostConstants,
        concurrent: usize,
    ) -> bool {
        let a = c_cos(app, k, 1, 20, 1000, concurrent);
        let b = c_cos(app, k, app.freeze_idx(), 20, 1000, concurrent);
        b >= a
    }

    /// Obs 1: T_Data is monotone in l_split.
    pub fn t_data_monotone_in_output(app: &AppProfile, i: usize, j: usize) -> bool {
        (app.out_bytes(i) <= app.out_bytes(j))
            == (t_data(app, i, 1000, 1e6) <= t_data(app, j, 1000, 1e6))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scale;
    use crate::model::profiles::{ArtifactsMeta, ModelProfile, ScaleMeta, UnitKind, UnitMeta};
    use std::sync::Arc;

    fn app() -> AppProfile {
        let unit = |index: usize, out: u64| UnitMeta {
            index,
            name: format!("u{index}"),
            kind: UnitKind::Conv,
            out_shape: vec![out as usize / 4],
            out_bytes_per_sample: out,
            param_count: 10,
            param_bytes: 40,
            flops_per_sample: 100,
        };
        let meta = ScaleMeta {
            input_shape: vec![250],
            input_bytes_per_sample: 1000,
            num_classes: 10,
            units: (1..=6)
                .map(|i| unit(i, 1000 >> i.min(5)))
                .collect(),
        };
        let p = Arc::new(ModelProfile {
            name: "toy".into(),
            num_units: 6,
            freeze_idx: 5,
            micro_batch: 4,
            param_seed: 42,
            tiny: meta.clone(),
            paper: meta,
            artifacts: ArtifactsMeta {
                units: (1..=6).map(|i| (i, format!("u{i}"), 1)).collect(),
                train_grads: "tg".into(),
                apply_update: "au".into(),
                tail_input_shape: vec![8],
                tail_num_params: 1,
            },
            param_files: vec![vec!["a".into()]; 6],
            params_dir: "params".into(),
        });
        AppProfile::new(p, Scale::Tiny)
    }

    #[test]
    fn t_data_drops_with_later_split() {
        let a = app();
        assert!(t_data(&a, 1, 1000, 1e6) > t_data(&a, 5, 1000, 1e6));
    }

    #[test]
    fn concurrency_scales_cos_time() {
        let a = app();
        let k = CostConstants::default();
        let one = c_cos(&a, &k, 3, 20, 1000, 1);
        let four = c_cos(&a, &k, 3, 20, 1000, 4);
        assert!((four / one - 4.0).abs() < 1e-9);
    }

    #[test]
    fn observations_hold() {
        let a = app();
        let k = CostConstants::default();
        assert!(observations::deeper_split_costs_more_cos(&a, &k, 4));
        assert!(observations::t_data_monotone_in_output(&a, 1, 4));
    }

    #[test]
    fn sec73_tradeoff_reproducible() {
        // With many concurrent tenants, an earlier split (larger output,
        // fewer pushed-down units) can beat splitting at the freeze layer
        // — the §7.3 DenseNet observation.
        let a = app();
        let k = CostConstants {
            c12: 1.0, // expensive COS compute per unit
            ..CostConstants::default()
        };
        let early =
            predict(&a, &k, 1, 20, 100, 1000, 4, 1e9).total();
        let at_freeze =
            predict(&a, &k, 5, 20, 100, 1000, 4, 1e9).total();
        assert!(early < at_freeze);
    }
}
