//! Experiment harness: one-call assembly of the full system.
//!
//! Everything the paper's testbed has, in-process: storage cluster →
//! proxy (+ embedded Hapi server) on a real TCP port, a shaped client
//! link, dataset materialisation, and client constructors for Hapi and
//! every competitor.  Examples, integration tests and all the fig/table
//! benches build on this.
//!
//! The testbed follows `cfg.backend`: with `BackendKind::Hlo` it loads
//! the AOT profiles/artifacts from `make artifacts`; with
//! `BackendKind::Sim` it runs entirely from the built-in synthetic
//! profiles and the deterministic `SimExecutor` — a fresh clone can
//! launch it with `HapiConfig::sim()` and no artifacts at all.

use std::sync::Arc;

use crate::baseline::AllInCosClient;
use crate::client::{DatasetRef, DatasetSpec, HapiClient};
use crate::config::HapiConfig;
use crate::cos::proxy::{Proxy, ProxyConfig, ProxyMode};
use crate::cos::storage::StorageCluster;
use crate::error::Result;
use crate::metrics::Registry;
use crate::model::ModelRegistry;
use crate::netsim::Topology;
use crate::profiler::AppProfile;
use crate::runtime::{DeviceKind, Engine, ExecBackend, ModelArtifacts};
use crate::server::HapiServer;

pub struct Testbed {
    pub cfg: HapiConfig,
    pub engine: Arc<Engine>,
    pub models: ModelRegistry,
    pub cluster: Arc<StorageCluster>,
    pub server: Arc<HapiServer>,
    pub registry: Registry,
    /// One proxy front end per network path (`cfg.net_paths`); all
    /// share the cluster, the embedded Hapi server, and the registry.
    proxies: Vec<Proxy>,
    /// The constrained compute-tier ↔ COS network (shared by all
    /// tenants): per-path token buckets under the optional client-NIC
    /// aggregate cap.  One path ≡ the paper's single shaped link.
    pub net: Topology,
}

impl Testbed {
    pub fn launch(cfg: HapiConfig) -> Result<Testbed> {
        Self::launch_with_mode(cfg, ProxyMode::Decoupled)
    }

    pub fn launch_with_mode(cfg: HapiConfig, mode: ProxyMode) -> Result<Testbed> {
        crate::util::logging::init();
        let registry = Registry::new();
        let engine = Engine::cpu()?;
        let models = ModelRegistry::for_config(&cfg)?;
        let cluster = Arc::new(match cfg.storage_read_rate {
            None => StorageCluster::new(cfg.storage_nodes, cfg.replicas),
            Some(rate) => {
                let nodes = (0..cfg.storage_nodes)
                    .map(|i| {
                        Arc::new(
                            crate::cos::StorageNode::new(format!("node{i}"))
                                .with_read_rate(rate),
                        )
                    })
                    .collect();
                StorageCluster::from_nodes(nodes, cfg.replicas)
            }
        });
        let net = cfg.topology();
        let server = HapiServer::new(
            engine.clone(),
            models.clone(),
            cluster.clone(),
            cfg.clone(),
            registry.clone(),
        );
        // With the queueing-delay model on, the planner's bounded
        // admission sees the network's load: the cap shrinks as path
        // utilisation rises (tf.data-service-style backpressure from
        // the server-visible queue signal).  Without the model the
        // signal reads 0 and the cap stays at its configured value.
        if cfg.path_queue_model {
            let signal_net = net.clone();
            server.planner().set_queue_signal(Arc::new(move || {
                signal_net.peak_utilisation()
            }));
        }
        // Do not cap request concurrency below what the devices'
        // admission control allows: the paper serves each POST in its
        // own process.  The sharded client keeps up to
        // `resolved_fanout` POSTs outstanding inside the planner's
        // gather window; size the pool so the window actually sees the
        // whole burst (16 covers any single-tenant bench).
        let shards_per_iter =
            (cfg.train_batch / cfg.object_samples).max(1);
        let compute_workers =
            16.max(cfg.resolved_fanout(shards_per_iter));
        // One proxy front end per path — the multi-proxy COS face the
        // paper's S3-style testbed reads through.  All instances share
        // the cluster and the embedded server, so planner/devices stay
        // global while transport parallelises.
        let proxies = (0..net.num_paths())
            .map(|path_id| {
                Proxy::start(
                    cluster.clone(),
                    server.clone(),
                    ProxyConfig {
                        mode,
                        compute_workers,
                        io_workers: 8,
                        path_id,
                    },
                    registry.clone(),
                )
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Testbed {
            cfg,
            engine,
            models,
            cluster,
            server,
            registry,
            proxies,
            net,
        })
    }

    /// Path-0 front end (the classic single-proxy address).
    pub fn addr(&self) -> String {
        self.proxies[0].addr().to_string()
    }

    /// Every front end's address, index-aligned with `net`'s paths.
    pub fn addrs(&self) -> Vec<String> {
        self.proxies.iter().map(|p| p.addr().to_string()).collect()
    }

    /// Fail-stop the front end serving `path` mid-run (scenario chaos:
    /// established connections die, new ones are refused) — see
    /// [`Proxy::fail`].  The address stays valid for the eventual
    /// [`Testbed::restart_proxy`].
    pub fn crash_proxy(&self, path: usize) {
        self.proxies[path].fail();
    }

    /// Bring a crashed front end back on its original address — see
    /// [`Proxy::recover`].
    pub fn restart_proxy(&self, path: usize) {
        self.proxies[path].recover();
    }

    /// Whether `path`'s front end is currently crashed.
    pub fn proxy_failed(&self, path: usize) -> bool {
        self.proxies[path].is_failed()
    }

    /// Gray-stall the front end serving `path`: requests are read but
    /// never answered until [`Testbed::unstall_proxy`] — see
    /// [`Proxy::stall`].
    pub fn stall_proxy(&self, path: usize) {
        self.proxies[path].stall();
    }

    /// Clear a gray stall on `path`'s front end.
    pub fn unstall_proxy(&self, path: usize) {
        self.proxies[path].unstall();
    }

    /// Corrupt `pct`% of `path`'s response frames on the wire (0
    /// clears) — see [`Proxy::set_corrupt`].
    pub fn set_corrupt_frames(&self, path: usize, pct: u64) {
        self.proxies[path].set_corrupt(pct);
    }

    /// Flap `path`'s front end: alternate `period` down / `period` up
    /// starting with a down window; cleared by
    /// [`Testbed::restart_proxy`] — see [`Proxy::flap`].
    pub fn flap_proxy(&self, path: usize, period: std::time::Duration) {
        self.proxies[path].flap(period);
    }

    pub fn app(&self, model: &str) -> Result<AppProfile> {
        Ok(AppProfile::new(self.models.get(model)?, self.cfg.scale))
    }

    /// The execution backend clients should use, per `cfg.backend`.
    pub fn backend(&self, model: &str) -> Result<ExecBackend> {
        let profile = self.models.get(model)?;
        ExecBackend::for_model(&self.cfg, &self.engine, profile)
    }

    /// HLO artifacts for `model` (experiment binaries on the HLO path).
    pub fn artifacts(&self, model: &str) -> Result<Arc<ModelArtifacts>> {
        let profile = self.models.get(model)?;
        Ok(Arc::new(ModelArtifacts::load(
            self.engine.clone(),
            profile,
            self.cfg.model_dir(model),
        )?))
    }

    /// Generate + store a dataset shaped for `model`, returning the
    /// reference and the labels in global order.
    pub fn dataset(
        &self,
        name: &str,
        model: &str,
        num_samples: usize,
    ) -> Result<(DatasetRef, Vec<i32>)> {
        let app = self.app(model)?;
        let spec = DatasetSpec {
            name: name.to_string(),
            input_shape: app.meta().input_shape.clone(),
            num_classes: app.meta().num_classes,
            num_samples,
            shard_samples: self.cfg.object_samples,
            seed: self.cfg.seed,
        };
        let labels: Vec<i32> =
            spec.shards().flat_map(|(_, l)| l).collect();
        let ds = spec.materialize(&self.cluster)?;
        Ok((ds, labels))
    }

    pub fn hapi_client(
        &self,
        model: &str,
        device: DeviceKind,
    ) -> Result<HapiClient> {
        let mut client = HapiClient::from_backend(
            self.app(model)?,
            self.backend(model)?,
            self.cfg.clone(),
            self.addrs(),
            self.net.clone(),
            device,
            None,
        );
        client.set_registry(self.registry.clone());
        Ok(client)
    }

    pub fn baseline_client(
        &self,
        model: &str,
        device: DeviceKind,
    ) -> Result<HapiClient> {
        let mut client = HapiClient::from_backend_baseline(
            self.app(model)?,
            self.backend(model)?,
            self.cfg.clone(),
            self.addrs(),
            self.net.clone(),
            device,
        );
        client.set_registry(self.registry.clone());
        Ok(client)
    }

    pub fn static_freeze_client(
        &self,
        model: &str,
        device: DeviceKind,
    ) -> Result<HapiClient> {
        let app = self.app(model)?;
        let freeze = app.freeze_idx();
        let mut client = HapiClient::from_backend(
            app,
            self.backend(model)?,
            self.cfg.clone(),
            self.addrs(),
            self.net.clone(),
            device,
            Some(freeze),
        );
        client.set_registry(self.registry.clone());
        Ok(client)
    }

    pub fn all_in_cos_client(&self, model: &str) -> Result<AllInCosClient> {
        let mut client = AllInCosClient::new(
            self.app(model)?,
            self.cfg.clone(),
            self.addrs(),
            self.net.clone(),
        );
        client.set_registry(self.registry.clone());
        Ok(client)
    }

    pub fn stop(self) {
        for proxy in self.proxies {
            proxy.stop();
        }
    }
}
