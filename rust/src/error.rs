//! Crate-wide error type.
//!
//! Every layer reports through [`Error`]; the variants mirror the failure
//! domains of the system (COS protocol, artifacts, XLA runtime, simulated
//! device OOM, algorithm infeasibility) so call sites can match on what
//! actually went wrong — in particular [`Error::Oom`], which the batch
//! adaptation experiments (§7.7) rely on distinguishing from hard faults.
//!
//! Hand-written `Display`/`From` impls (no `thiserror`): the offline
//! build is dependency-free.

use std::fmt;
use std::io;

#[cfg(not(feature = "pjrt"))]
use crate::runtime::xla_shim as xla;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    Io(io::Error),
    Json(String),
    Config(String),
    Artifact(String),
    Xla(String),
    /// Simulated accelerator out-of-memory (the CUDA OOM analogue).
    Oom {
        needed: u64,
        free: u64,
        capacity: u64,
    },
    /// Planner admission queue full (bounded admission): the request
    /// was rejected *before* queueing — a backpressure signal the
    /// client maps to retry-with-backoff, not a hard fault.
    Busy {
        queued: usize,
        cap: usize,
    },
    Protocol(String),
    Cos(String),
    /// Batch-adaptation optimisation infeasible even at minimum batch.
    Infeasible(String),
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json(m) => write!(f, "json: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
            Error::Oom {
                needed,
                free,
                capacity,
            } => write!(
                f,
                "device OOM: need {needed} bytes, free {free} of {capacity}"
            ),
            Error::Busy { queued, cap } => write!(
                f,
                "planner busy: admission queue full \
                 ({queued} queued, cap {cap}); retry later"
            ),
            Error::Protocol(m) => write!(f, "protocol: {m}"),
            Error::Cos(m) => write!(f, "object store: {m}"),
            Error::Infeasible(m) => {
                write!(f, "batch adaptation infeasible: {m}")
            }
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for Error {
    fn from(e: io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }

    /// True when the error is the simulated device OOM — including OOMs
    /// raised on the COS and surfaced to the client as a wire-level
    /// error string (the `device OOM` marker is stable; see
    /// [`Error::Oom`]'s Display form).
    pub fn is_oom(&self) -> bool {
        match self {
            Error::Oom { .. } => true,
            Error::Cos(m) | Error::Other(m) => m.contains("device OOM"),
            _ => false,
        }
    }

    /// True when the error is the planner's bounded-admission reject —
    /// including rejects raised on the COS and surfaced to the client
    /// as a wire-level error string (the `planner busy` marker is
    /// stable; see [`Error::Busy`]'s Display form).  The client's
    /// fetch path maps this to retry-with-backoff.
    pub fn is_rejected(&self) -> bool {
        match self {
            Error::Busy { .. } => true,
            Error::Cos(m) | Error::Other(m) => {
                m.contains("planner busy")
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oom_display_is_stable() {
        let e = Error::Oom {
            needed: 10,
            free: 2,
            capacity: 8,
        };
        assert_eq!(e.to_string(), "device OOM: need 10 bytes, free 2 of 8");
        assert!(e.is_oom());
        assert!(Error::Cos(e.to_string()).is_oom());
        assert!(!Error::Config("x".into()).is_oom());
    }

    #[test]
    fn busy_display_is_stable() {
        let e = Error::Busy { queued: 5, cap: 4 };
        assert_eq!(
            e.to_string(),
            "planner busy: admission queue full \
             (5 queued, cap 4); retry later"
        );
        assert!(e.is_rejected());
        assert!(!e.is_oom());
        assert!(Error::Cos(e.to_string()).is_rejected());
        assert!(!Error::Config("x".into()).is_rejected());
    }

    #[test]
    fn io_source_is_preserved() {
        let e: Error =
            io::Error::new(io::ErrorKind::NotFound, "gone").into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
