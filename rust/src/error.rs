//! Crate-wide error type.
//!
//! Every layer reports through [`Error`]; the variants mirror the failure
//! domains of the system (COS protocol, artifacts, XLA runtime, simulated
//! device OOM, algorithm infeasibility) so call sites can match on what
//! actually went wrong — in particular [`Error::Oom`], which the batch
//! adaptation experiments (§7.7) rely on distinguishing from hard faults.
//!
//! Hand-written `Display`/`From` impls (no `thiserror`): the offline
//! build is dependency-free.

use std::fmt;
use std::io;

#[cfg(not(feature = "pjrt"))]
use crate::runtime::xla_shim as xla;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    Io(io::Error),
    Json(String),
    Config(String),
    Artifact(String),
    Xla(String),
    /// Simulated accelerator out-of-memory (the CUDA OOM analogue).
    Oom {
        needed: u64,
        free: u64,
        capacity: u64,
    },
    /// Planner admission queue full (bounded admission): the request
    /// was rejected *before* queueing — a backpressure signal the
    /// client maps to retry-with-backoff, not a hard fault.
    Busy {
        queued: usize,
        cap: usize,
    },
    /// An I/O deadline (`io_deadline_ms`) expired mid-exchange: the
    /// peer accepted the connection but stopped making progress — the
    /// gray-failure analogue of a crash.  Retryable: the slot drops
    /// its connection and the fetch re-lands elsewhere.
    Timeout(String),
    /// Frame checksum mismatch (`frame_integrity`): the payload was
    /// corrupted in flight and was **not** consumed.  Retryable: the
    /// same bytes re-fetched are overwhelmingly likely to arrive
    /// clean.
    Integrity(String),
    Protocol(String),
    Cos(String),
    /// Batch-adaptation optimisation infeasible even at minimum batch.
    Infeasible(String),
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json(m) => write!(f, "json: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
            Error::Oom {
                needed,
                free,
                capacity,
            } => write!(
                f,
                "device OOM: need {needed} bytes, free {free} of {capacity}"
            ),
            Error::Busy { queued, cap } => write!(
                f,
                "planner busy: admission queue full \
                 ({queued} queued, cap {cap}); retry later"
            ),
            Error::Timeout(m) => write!(f, "i/o timeout: {m}"),
            Error::Integrity(m) => write!(f, "frame integrity: {m}"),
            Error::Protocol(m) => write!(f, "protocol: {m}"),
            Error::Cos(m) => write!(f, "object store: {m}"),
            Error::Infeasible(m) => {
                write!(f, "batch adaptation infeasible: {m}")
            }
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for Error {
    fn from(e: io::Error) -> Self {
        // Socket deadlines surface as TimedOut (or WouldBlock on some
        // platforms' `set_read_timeout`); classify them as the gray
        // timeout, not a generic I/O fault, so retry/breaker logic can
        // tell a stalled peer from a severed one.
        match e.kind() {
            io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => {
                Error::Timeout(e.to_string())
            }
            _ => Error::Io(e),
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }

    /// True when the error is the simulated device OOM — including OOMs
    /// raised on the COS and surfaced to the client as a wire-level
    /// error string (the `device OOM` marker is stable; see
    /// [`Error::Oom`]'s Display form).
    pub fn is_oom(&self) -> bool {
        match self {
            Error::Oom { .. } => true,
            Error::Cos(m) | Error::Other(m) => m.contains("device OOM"),
            _ => false,
        }
    }

    /// True when the error is the planner's bounded-admission reject —
    /// including rejects raised on the COS and surfaced to the client
    /// as a wire-level error string (the `planner busy` marker is
    /// stable; see [`Error::Busy`]'s Display form).  The client's
    /// fetch path maps this to retry-with-backoff.
    pub fn is_rejected(&self) -> bool {
        match self {
            Error::Busy { .. } => true,
            Error::Cos(m) | Error::Other(m) => {
                m.contains("planner busy")
            }
            _ => false,
        }
    }

    /// True when the error is an expired I/O deadline — including
    /// timeouts surfaced as a wire-level error string (the
    /// `i/o timeout` marker is stable; see [`Error::Timeout`]'s
    /// Display form).
    pub fn is_timeout(&self) -> bool {
        match self {
            Error::Timeout(_) => true,
            Error::Cos(m) | Error::Other(m) => m.contains("i/o timeout"),
            _ => false,
        }
    }

    /// True when the error is a frame checksum mismatch — including
    /// mismatches the proxy detected on a request and surfaced as a
    /// wire-level error string (the `frame integrity` marker is
    /// stable; see [`Error::Integrity`]'s Display form).
    pub fn is_integrity(&self) -> bool {
        match self {
            Error::Integrity(_) => true,
            Error::Cos(m) | Error::Other(m) => {
                m.contains("frame integrity")
            }
            _ => false,
        }
    }

    /// The retryable-vs-fatal split the sharded engine's
    /// retry-on-another-connection and the client backoff loop unify
    /// on.  Transport-domain faults (severed/stalled/corrupted
    /// connections, busy planners, garbled frames, server-side error
    /// strings) are retryable: a fresh attempt on a fresh connection
    /// can legitimately succeed.  Resource and logic faults (device
    /// OOM, infeasible batch plans, bad config/artifacts, compute
    /// errors) are fatal: retrying re-runs the same deterministic
    /// failure.
    pub fn is_retryable(&self) -> bool {
        !matches!(
            self,
            Error::Oom { .. }
                | Error::Infeasible(_)
                | Error::Config(_)
                | Error::Artifact(_)
                | Error::Xla(_)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oom_display_is_stable() {
        let e = Error::Oom {
            needed: 10,
            free: 2,
            capacity: 8,
        };
        assert_eq!(e.to_string(), "device OOM: need 10 bytes, free 2 of 8");
        assert!(e.is_oom());
        assert!(Error::Cos(e.to_string()).is_oom());
        assert!(!Error::Config("x".into()).is_oom());
    }

    #[test]
    fn busy_display_is_stable() {
        let e = Error::Busy { queued: 5, cap: 4 };
        assert_eq!(
            e.to_string(),
            "planner busy: admission queue full \
             (5 queued, cap 4); retry later"
        );
        assert!(e.is_rejected());
        assert!(!e.is_oom());
        assert!(Error::Cos(e.to_string()).is_rejected());
        assert!(!Error::Config("x".into()).is_rejected());
    }

    #[test]
    fn io_source_is_preserved() {
        let e: Error =
            io::Error::new(io::ErrorKind::NotFound, "gone").into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn timeout_display_is_stable() {
        let e = Error::Timeout("read deadline expired".into());
        assert_eq!(e.to_string(), "i/o timeout: read deadline expired");
        assert!(e.is_timeout());
        assert!(Error::Cos(e.to_string()).is_timeout());
        assert!(!Error::Config("x".into()).is_timeout());
        // Socket-level deadline kinds classify as Timeout on conversion.
        let t: Error =
            io::Error::new(io::ErrorKind::TimedOut, "slow").into();
        assert!(t.is_timeout());
        let w: Error =
            io::Error::new(io::ErrorKind::WouldBlock, "slow").into();
        assert!(w.is_timeout());
    }

    #[test]
    fn integrity_display_is_stable() {
        let e = Error::Integrity("checksum mismatch".into());
        assert_eq!(e.to_string(), "frame integrity: checksum mismatch");
        assert!(e.is_integrity());
        assert!(Error::Cos(e.to_string()).is_integrity());
        assert!(!Error::Protocol("x".into()).is_integrity());
    }

    #[test]
    fn retryable_vs_fatal_split() {
        for retryable in [
            Error::Timeout("t".into()),
            Error::Integrity("i".into()),
            Error::Busy { queued: 1, cap: 1 },
            Error::Io(io::Error::new(io::ErrorKind::BrokenPipe, "x")),
            Error::Cos("server said no".into()),
            Error::Protocol("garbled".into()),
            Error::Json("garbled".into()),
            Error::Other("flaky".into()),
        ] {
            assert!(retryable.is_retryable(), "{retryable}");
        }
        for fatal in [
            Error::Oom { needed: 2, free: 1, capacity: 1 },
            Error::Infeasible("min batch".into()),
            Error::Config("bad knob".into()),
            Error::Artifact("missing".into()),
            Error::Xla("compile".into()),
        ] {
            assert!(!fatal.is_retryable(), "{fatal}");
        }
    }
}
