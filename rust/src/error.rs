//! Crate-wide error type.
//!
//! Every layer reports through [`Error`]; the variants mirror the failure
//! domains of the system (COS protocol, artifacts, XLA runtime, simulated
//! device OOM, algorithm infeasibility) so call sites can match on what
//! actually went wrong — in particular [`Error::Oom`], which the batch
//! adaptation experiments (§7.7) rely on distinguishing from hard faults.

use std::io;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("io: {0}")]
    Io(#[from] io::Error),

    #[error("json: {0}")]
    Json(String),

    #[error("config: {0}")]
    Config(String),

    #[error("artifact: {0}")]
    Artifact(String),

    #[error("xla: {0}")]
    Xla(String),

    /// Simulated accelerator out-of-memory (the CUDA OOM analogue).
    #[error("device OOM: need {needed} bytes, free {free} of {capacity}")]
    Oom {
        needed: u64,
        free: u64,
        capacity: u64,
    },

    #[error("protocol: {0}")]
    Protocol(String),

    #[error("object store: {0}")]
    Cos(String),

    /// Batch-adaptation optimisation infeasible even at minimum batch.
    #[error("batch adaptation infeasible: {0}")]
    Infeasible(String),

    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }

    /// True when the error is the simulated device OOM — including OOMs
    /// raised on the COS and surfaced to the client as a wire-level
    /// error string (the `device OOM` marker is stable; see
    /// [`Error::Oom`]'s Display form).
    pub fn is_oom(&self) -> bool {
        match self {
            Error::Oom { .. } => true,
            Error::Cos(m) | Error::Other(m) => m.contains("device OOM"),
            _ => false,
        }
    }
}
