//! The §7 competitors.
//!
//! - **BASELINE** — train entirely on the compute tier, streaming raw
//!   images from the COS with pipelined GETs.  Built as a
//!   [`crate::client::HapiClient`] with split index 0
//!   (`HapiClient::from_backend_baseline`), so training parameters and
//!   pipelining are identical to Hapi's (§6).
//! - **STATIC_FREEZE** — split statically at the freeze layer (§7.3's
//!   strawman): `HapiClient::from_backend` with
//!   `split_override = Some(freeze_idx)`.
//! - **ALL_IN_COS** — push *both* TL phases down (§5.1's limitation
//!   study, Fig 12): [`AllInCosClient`] sends one `all_in_cos` POST per
//!   object; the server extracts features *and* trains at the training
//!   batch size, returning only the loss.
//!
//! All three ride the same [`pipeline`] sharded prefetch engine as Hapi
//! — the `pipeline_depth` and `fetch_fanout` knobs apply uniformly, so
//! depth and fanout sweeps compare like with like.

use std::sync::Mutex;

use crate::client::{pipeline, DatasetRef, EpochStats};
use crate::config::HapiConfig;
use crate::cos::protocol::CosConnection;
use crate::error::Result;
use crate::metrics::Registry;
use crate::netsim::Topology;
use crate::profiler::AppProfile;
use crate::server::request::{PostRequest, RequestMode};

/// ALL_IN_COS: the whole TL computation next to storage.
pub struct AllInCosClient {
    app: AppProfile,
    cfg: HapiConfig,
    /// One proxy address per network path, index-aligned with `net`.
    addrs: Vec<String>,
    net: Topology,
    next_id: std::sync::atomic::AtomicU64,
    /// Stable identity reported in every POST header so the planner
    /// gathers this tenant's burst in its own lane.
    client_id: u64,
    registry: Registry,
}

impl AllInCosClient {
    pub fn new(
        app: AppProfile,
        cfg: HapiConfig,
        addrs: Vec<String>,
        net: Topology,
    ) -> AllInCosClient {
        assert!(
            !addrs.is_empty(),
            "client needs at least one proxy address"
        );
        let client_id = crate::client::resolve_client_id(&cfg);
        AllInCosClient {
            app,
            cfg,
            addrs,
            net,
            next_id: std::sync::atomic::AtomicU64::new(1),
            client_id,
            registry: Registry::new(),
        }
    }

    /// The identity this client reports to the planner's gather lanes.
    pub fn client_id(&self) -> u64 {
        self.client_id
    }

    /// Route pipeline metrics into a shared registry.
    pub fn set_registry(&mut self, registry: Registry) {
        self.registry = registry;
    }

    /// Run one epoch fully on the COS; the client only sequences
    /// requests and collects losses (no local compute, no decoupling:
    /// the COS batch bound equals the training batch size).  Requests
    /// flow through the same sharded fetch engine as Hapi's —
    /// `pipeline_depth` training steps in flight over a `fetch_fanout`
    /// connection pool, losses delivered in shard order.
    pub fn train_epoch(&self, ds: &DatasetRef) -> Result<EpochStats> {
        let mem = self.app.memory();
        let freeze = self.app.freeze_idx();
        let mut stats = EpochStats::default();
        let rx0 = self.net.stats().rx_bytes();
        let tx0 = self.net.stats().tx_bytes();
        let jobs = pipeline::jobs_for(ds.num_shards, 1);
        // One POST per iteration (one shard per job): the lane burst is
        // the pipeline depth, capped by the connection pool.
        let fanout = self.cfg.resolved_fanout(1);
        let burst_width =
            pipeline::planner_burst_width(self.cfg.pipeline_depth, 1, fanout);
        // Connection pool: `fanout` lazily-connected slots, reused
        // across requests; a connection that errored is dropped so its
        // slot reconnects (the engine retries on another slot).  Like
        // the Hapi client's pool, each slot is routed to a network
        // path (and that path's proxy front end) by the transport
        // scheduler.
        let pool: Vec<Mutex<Option<(usize, CosConnection)>>> =
            (0..fanout).map(|_| Mutex::new(None)).collect();
        // ALL_IN_COS rides the scheduler for routing and the
        // `pipeline.pathN.*` accounting, with one caveat: hedging is
        // forced off (an `all_in_cos` POST *trains* on the server —
        // one SGD step per request — so a duplicated request would
        // double-apply an update; only idempotent fetches may race).
        // Goodput-driven re-pinning cannot fire on these zero-payload
        // responses (only the loss returns, so the estimates stay at
        // the topology seeds), but every request still records a
        // latency sample, and the analytic transport policy's latency
        // leg re-pins slots away from a path whose p95 degrades —
        // merely-slow front ends are evacuated, not just fail-stopped
        // ones (whose fetch *errors* decay the goodput estimate).
        // The ~0 per-path byte sums still merge into
        // `pipeline.bytes`.
        let scheduler = crate::client::TransportScheduler::new(
            &self.cfg,
            self.client_id,
            &self.net,
            fanout,
            &self.registry,
        )
        .without_hedging();
        let report = pipeline::run_sharded_with(
            self.cfg.pipeline_depth,
            fanout,
            &jobs,
            &self.registry,
            true,
            &scheduler,
            |_job| (),
            |ctx, _: &(), job, shard_pos| {
                let shard = job.shards[shard_pos];
                let samples = ds
                    .shard_samples
                    .min(ds.num_samples - shard * ds.shard_samples);
                let mut dims = vec![samples];
                dims.extend(&ds.input_shape);
                let req = PostRequest {
                    id: self
                        .next_id
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed),
                    model: self.app.model.name.clone(),
                    split_idx: freeze,
                    object: crate::cos::ObjectKey::shard(&ds.name, shard),
                    labels_object: format!(
                        "{}/labels_{shard:05}",
                        ds.name
                    ),
                    input_dims: dims,
                    // No decoupling: the server must process at the
                    // training granularity (bounded by the object here,
                    // as one object is one request).
                    b_max: self.cfg.train_batch.min(samples),
                    mem_data_per_sample: mem
                        .fe_data_bytes_per_sample(freeze)
                        .max(mem.all_in_cos_bytes(samples) / samples as u64),
                    mem_model_bytes: mem.fe_model_bytes(freeze),
                    burst_width,
                    client_id: self.client_id,
                    mode: RequestMode::AllInCos,
                };
                let path = ctx.path;
                let (header, _body) = CosConnection::with_pooled(
                    &pool[ctx.conn],
                    path,
                    &self.addrs[path % self.addrs.len()],
                    self.net.path(path),
                    |conn| conn.post(req.to_json(), Vec::new()),
                )?;
                let loss = header.get("loss")?.as_f64()? as f32;
                Ok(pipeline::ShardFetched {
                    payload: loss,
                    bytes: 0, // only the loss crosses the wire
                })
            },
            |_job, _: &(), mut parts| {
                Ok(parts.pop().expect("one shard per job"))
            },
            |delivery| {
                stats.comm += delivery.stall;
                stats.iterations += 1;
                stats.loss.push(delivery.payload);
                stats.accuracy.push(0.0);
                Ok(())
            },
        )?;
        stats.max_inflight = report.inflight_max;
        stats.bytes_from_cos = self.net.stats().rx_bytes() - rx0;
        stats.bytes_to_cos = self.net.stats().tx_bytes() - tx0;
        Ok(stats)
    }
}

// The old `construct` convenience module is gone: every in-repo caller
// builds competitors through `harness::Testbed`'s client constructors,
// which also wire the shared metrics registry.
