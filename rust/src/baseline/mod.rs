//! The §7 competitors.
//!
//! - **BASELINE** — train entirely on the compute tier, streaming raw
//!   images from the COS with pipelined GETs.  Built as a
//!   [`HapiClient`] with split index 0 (`HapiClient::new_baseline`), so
//!   training parameters and pipelining are identical to Hapi's (§6).
//! - **STATIC_FREEZE** — split statically at the freeze layer (§7.3's
//!   strawman): `HapiClient::new` with `split_override = freeze_idx`.
//! - **ALL_IN_COS** — push *both* TL phases down (§5.1's limitation
//!   study, Fig 12): [`AllInCosClient`] sends one `all_in_cos` POST per
//!   object; the server extracts features *and* trains at the training
//!   batch size, returning only the loss.

use std::sync::Arc;

use crate::client::{DatasetRef, EpochStats};
use crate::config::HapiConfig;
use crate::cos::protocol::CosConnection;
use crate::error::Result;
use crate::netsim::Link;
use crate::profiler::AppProfile;
use crate::server::request::{PostRequest, RequestMode};

/// ALL_IN_COS: the whole TL computation next to storage.
pub struct AllInCosClient {
    app: AppProfile,
    cfg: HapiConfig,
    addr: String,
    link: Link,
    next_id: std::sync::atomic::AtomicU64,
}

impl AllInCosClient {
    pub fn new(
        app: AppProfile,
        cfg: HapiConfig,
        addr: String,
        link: Link,
    ) -> AllInCosClient {
        AllInCosClient {
            app,
            cfg,
            addr,
            link,
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Run one epoch fully on the COS; the client only sequences
    /// requests and collects losses (no local compute, no decoupling:
    /// the COS batch bound equals the training batch size).
    pub fn train_epoch(&self, ds: &DatasetRef) -> Result<EpochStats> {
        let mem = self.app.memory();
        let freeze = self.app.freeze_idx();
        let mut stats = EpochStats::default();
        let rx0 = self.link.stats().rx_bytes();
        let tx0 = self.link.stats().tx_bytes();
        let mut conn =
            CosConnection::connect(&self.addr, self.link.clone())?;
        for shard in 0..ds.num_shards {
            let samples = ds
                .shard_samples
                .min(ds.num_samples - shard * ds.shard_samples);
            let mut dims = vec![samples];
            dims.extend(&ds.input_shape);
            let req = PostRequest {
                id: self
                    .next_id
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed),
                model: self.app.model.name.clone(),
                split_idx: freeze,
                object: crate::cos::ObjectKey::shard(&ds.name, shard),
                labels_object: format!("{}/labels_{shard:05}", ds.name),
                input_dims: dims,
                // No decoupling: the server must process at the training
                // granularity (bounded by the object here, as one object
                // is one request).
                b_max: self.cfg.train_batch.min(samples),
                mem_data_per_sample: mem
                    .fe_data_bytes_per_sample(freeze)
                    .max(mem.all_in_cos_bytes(samples) / samples as u64),
                mem_model_bytes: mem.fe_model_bytes(freeze),
                mode: RequestMode::AllInCos,
            };
            let t0 = std::time::Instant::now();
            let (header, _body) = conn.post(req.to_json(), Vec::new())?;
            stats.comm += t0.elapsed();
            stats.iterations += 1;
            stats
                .loss
                .push(header.get("loss")?.as_f64()? as f32);
            stats.accuracy.push(0.0);
        }
        stats.bytes_from_cos = self.link.stats().rx_bytes() - rx0;
        stats.bytes_to_cos = self.link.stats().tx_bytes() - tx0;
        Ok(stats)
    }
}

/// Convenience constructors mirroring the paper's competitor names.
pub mod construct {
    use super::*;
    use crate::client::HapiClient;
    use crate::runtime::{DeviceKind, ModelArtifacts};

    pub fn baseline(
        app: AppProfile,
        arts: Arc<ModelArtifacts>,
        cfg: HapiConfig,
        addr: String,
        link: Link,
        device: DeviceKind,
    ) -> HapiClient {
        HapiClient::new_baseline(app, arts, cfg, addr, link, device)
    }

    pub fn hapi(
        app: AppProfile,
        arts: Arc<ModelArtifacts>,
        cfg: HapiConfig,
        addr: String,
        link: Link,
        device: DeviceKind,
    ) -> HapiClient {
        HapiClient::new(app, arts, cfg, addr, link, device, None)
    }

    pub fn static_freeze(
        app: AppProfile,
        arts: Arc<ModelArtifacts>,
        cfg: HapiConfig,
        addr: String,
        link: Link,
        device: DeviceKind,
    ) -> HapiClient {
        let freeze = app.freeze_idx();
        HapiClient::new(app, arts, cfg, addr, link, device, Some(freeze))
    }
}
