//! # Hapi — near-data transfer learning on cloud object stores
//!
//! Reproduction of *"Accelerating Transfer Learning with Near-Data
//! Computation on Cloud Object Stores"* as a three-layer Rust + JAX +
//! Pallas stack.  This crate is **Layer 3**: the paper's coordination
//! contribution plus every substrate it depends on.  Python runs only at
//! build time (`make artifacts`); the request path is pure Rust executing
//! AOT-compiled HLO through the XLA PJRT CPU client.
//!
//! ## Map
//!
//! - [`cos`] — the Swift-like cloud object store substrate (hash ring,
//!   storage nodes, proxy, wire protocol).
//! - [`netsim`] — token-bucket bandwidth shaping + byte metering for the
//!   compute-tier ↔ COS link (the paper's `tc` rate limits).
//! - [`model`]/[`profiler`] — per-unit model metadata and the §5.3 hybrid
//!   memory/size estimator.
//! - [`runtime`] — PJRT engine (HLO text → executable), `.tnsr` tensors,
//!   the simulated accelerator device (memory ledger + OOM + speed
//!   model; see DESIGN.md §2 for the substitution argument), and the
//!   artifact-free SimBackend (`runtime::sim`) behind the
//!   `runtime::ExecBackend` dispatch.
//! - [`split`] — the paper's Algorithm 1 (split-index selection).
//! - [`batch`] — the Eq. 4 batch-adaptation solver.
//! - [`server`]/[`client`] — the Hapi server (COS side) and client
//!   (compute tier); `client::pipeline` is the configurable-depth,
//!   sharded multi-connection cross-tier prefetch engine every
//!   competitor trains through (`pipeline_depth` × `fetch_fanout`).
//! - [`baseline`] — BASELINE / ALL_IN_COS / static-freeze-split
//!   competitors from §7.
//! - [`theory`] — the §4 cost model (Eqs. 1–3).
//! - [`policy`] — pluggable decision policies (split/batch/transport)
//!   behind traits, recorded decision traces (JSONL) and the offline
//!   policy-replay scorer behind `hapi policy-eval`.
//! - [`scenario`] — seed-replayable chaos scenarios over the testbed
//!   (the fuzzer's script generator, executor and invariant checks).
//! - [`util`], [`cli`], [`exec`], [`metrics`], [`benchkit`], [`workload`],
//!   [`config`] — substrates (no serde/clap/tokio/criterion offline; we
//!   build what we need).

pub mod analyze;
pub mod baseline;
pub mod batch;
pub mod benchkit;
pub mod cli;
pub mod client;
pub mod config;
pub mod cos;
pub mod error;
pub mod exec;
pub mod harness;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod policy;
pub mod profiler;
pub mod runtime;
pub mod scenario;
pub mod server;
pub mod split;
pub mod theory;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
