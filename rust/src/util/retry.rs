//! One retry loop for every client-side retry site.
//!
//! Three callers share this shape: the planner-busy backoff in the
//! client fetch path (capped exponential sleep), the sharded engine's
//! retry-on-another-connection (immediate, the re-route *is* the
//! backoff), and the gray-failure retries for [`Error::Timeout`] /
//! [`Error::Integrity`].  Keeping them on one helper means the cap,
//! the classifier hook and the per-attempt metric hook cannot drift
//! apart.
//!
//! The caller supplies three closures: `retryable` classifies an error
//! (see [`Error::is_retryable`] for the crate-wide retryable-vs-fatal
//! split), `on_retry` fires before each retry (metric increments,
//! re-routing), and `attempt` runs the operation with its 0-based
//! attempt index — later attempts can route differently.

use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Retry budget and pacing.  `max_retries` counts *re*-tries: the
/// operation runs at most `max_retries + 1` times.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    pub max_retries: u32,
    /// Sleep before the first retry; doubles per retry up to
    /// `backoff_cap`.  [`Duration::ZERO`] = retry immediately.
    pub backoff: Duration,
    pub backoff_cap: Duration,
    /// Non-zero: each sleep is jittered to 50–100% of its nominal
    /// value, deterministically from this seed — concurrent tenants
    /// backing off from the same busy planner de-synchronise instead
    /// of thundering back in lockstep.
    pub jitter_seed: u64,
}

impl RetryPolicy {
    /// Retry up to `max_retries` times with no sleep in between — the
    /// sharded engine's shape, where re-routing to another connection
    /// is the real remedy and waiting adds nothing.
    pub fn immediate(max_retries: u32) -> Self {
        RetryPolicy {
            max_retries,
            backoff: Duration::ZERO,
            backoff_cap: Duration::ZERO,
            jitter_seed: 0,
        }
    }

    /// Capped exponential backoff starting at `backoff`.
    pub fn backoff(
        max_retries: u32,
        backoff: Duration,
        backoff_cap: Duration,
    ) -> Self {
        RetryPolicy {
            max_retries,
            backoff,
            backoff_cap,
            jitter_seed: 0,
        }
    }

    /// Jitter the sleeps from `seed` (0 = no jitter).
    pub fn jitter(mut self, seed: u64) -> Self {
        self.jitter_seed = seed;
        self
    }
}

/// Run `attempt` under `policy`.  On an error that `retryable` accepts
/// while budget remains, `on_retry(attempt_idx, &err)` fires, the
/// backoff (if any) is slept, and the operation re-runs with the next
/// attempt index.  Fatal errors and budget exhaustion return the last
/// error unchanged.
pub fn run<T>(
    policy: &RetryPolicy,
    mut retryable: impl FnMut(&Error) -> bool,
    mut on_retry: impl FnMut(u32, &Error),
    mut attempt: impl FnMut(u32) -> Result<T>,
) -> Result<T> {
    let mut rng = (policy.jitter_seed != 0)
        .then(|| Rng::new(policy.jitter_seed));
    let mut sleep = policy.backoff;
    let mut tries = 0u32;
    loop {
        match attempt(tries) {
            Ok(v) => return Ok(v),
            Err(e) if tries < policy.max_retries && retryable(&e) => {
                on_retry(tries, &e);
                if !sleep.is_zero() {
                    let wait = match &mut rng {
                        Some(r) => {
                            sleep.mul_f64(0.5 + 0.5 * r.f32() as f64)
                        }
                        None => sleep,
                    };
                    std::thread::sleep(wait);
                    sleep = (sleep * 2).min(policy.backoff_cap);
                }
                tries += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_success_never_retries() {
        let mut hooks = 0;
        let v = run(
            &RetryPolicy::immediate(3),
            |_| true,
            |_, _| hooks += 1,
            |_| Ok(7),
        )
        .unwrap();
        assert_eq!(v, 7);
        assert_eq!(hooks, 0);
    }

    #[test]
    fn retries_until_success_with_attempt_indices() {
        let mut seen = Vec::new();
        let v = run(
            &RetryPolicy::immediate(5),
            |e| e.is_retryable(),
            |i, _| seen.push(i),
            |i| {
                if i < 3 {
                    Err(Error::other("flaky"))
                } else {
                    Ok(i)
                }
            },
        )
        .unwrap();
        assert_eq!(v, 3);
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn fatal_errors_propagate_immediately() {
        let mut hooks = 0;
        let err = run(
            &RetryPolicy::immediate(5),
            |e| e.is_retryable(),
            |_, _| hooks += 1,
            |_| -> Result<()> {
                Err(Error::Config("bad".into()))
            },
        )
        .unwrap_err();
        assert!(matches!(err, Error::Config(_)));
        assert_eq!(hooks, 0);
    }

    #[test]
    fn budget_exhaustion_returns_last_error() {
        let mut attempts = 0;
        let err = run(
            &RetryPolicy::immediate(2),
            |_| true,
            |_, _| {},
            |i| -> Result<()> {
                attempts += 1;
                Err(Error::other(format!("fail {i}")))
            },
        )
        .unwrap_err();
        assert_eq!(attempts, 3, "1 attempt + 2 retries");
        assert!(err.to_string().contains("fail 2"));
    }

    #[test]
    fn backoff_sleeps_and_caps() {
        let policy = RetryPolicy::backoff(
            3,
            Duration::from_millis(2),
            Duration::from_millis(4),
        );
        let t0 = std::time::Instant::now();
        let _ = run(
            &policy,
            |_| true,
            |_, _| {},
            |_| -> Result<()> { Err(Error::other("x")) },
        );
        // 2 + 4 + 4 ms of nominal sleep.
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn jitter_shrinks_but_never_inflates_the_sleep() {
        let policy = RetryPolicy::backoff(
            2,
            Duration::from_millis(20),
            Duration::from_millis(20),
        )
        .jitter(0x5eed);
        let t0 = std::time::Instant::now();
        let _ = run(
            &policy,
            |_| true,
            |_, _| {},
            |_| -> Result<()> { Err(Error::other("x")) },
        );
        let elapsed = t0.elapsed();
        // Two sleeps, each in [10, 20] ms.
        assert!(elapsed >= Duration::from_millis(19), "{elapsed:?}");
    }
}
