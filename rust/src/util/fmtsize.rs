//! Human-readable byte / duration formatting for reports and logs.

use std::time::Duration;

/// `1536 -> "1.5 KiB"`, `0 -> "0 B"`.
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if bytes < 1024 {
        return format!("{bytes} B");
    }
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if v >= 100.0 {
        format!("{v:.0} {}", UNITS[unit])
    } else {
        format!("{v:.1} {}", UNITS[unit])
    }
}

/// `Duration -> "1.25s" / "340ms" / "87µs"`.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 100.0 {
        format!("{s:.0}s")
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.0}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.0}µs", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes() {
        assert_eq!(fmt_bytes(0), "0 B");
        assert_eq!(fmt_bytes(1023), "1023 B");
        assert_eq!(fmt_bytes(1536), "1.5 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
        assert_eq!(fmt_bytes(200 * 1024 * 1024 * 1024), "200 GiB");
    }

    #[test]
    fn durations() {
        assert_eq!(fmt_duration(Duration::from_secs(120)), "120s");
        assert_eq!(fmt_duration(Duration::from_millis(1250)), "1.25s");
        assert_eq!(fmt_duration(Duration::from_millis(340)), "340ms");
        assert_eq!(fmt_duration(Duration::from_micros(87)), "87µs");
    }
}
