//! Minimal JSON: parse + serialize + typed accessors.
//!
//! The compile path (python/compile/aot.py) emits model profiles and
//! dataset presets as JSON; serde is not in the offline vendor set, so we
//! carry a small recursive-descent parser.  It supports the full JSON
//! grammar (objects, arrays, strings with escapes incl. `\uXXXX`, numbers,
//! bools, null) and is strict: trailing garbage and malformed input are
//! errors, not best-effort.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.  Objects use a `BTreeMap` so serialization is
/// deterministic (stable key order), which keeps config dumps diffable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------
    // Parsing
    // ---------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Json(format!("{}: {e}", path.as_ref().display()))
        })?;
        Json::parse(&text)
    }

    // ---------------------------------------------------------------
    // Typed accessors (ergonomic, error-reporting)
    // ---------------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::Json(format!("missing key {key:?}"))),
            _ => Err(Error::Json(format!("not an object (key {key:?})"))),
        }
    }

    /// `get` that tolerates absence.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Json(format!("not a number: {self:?}"))),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Json(format!("not a u64: {n}")));
        }
        Ok(n as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("not a string: {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Json(format!("not a bool: {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json(format!("not an array: {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json(format!("not an object: {self:?}"))),
        }
    }

    /// Array of unsigned integers → `Vec<usize>` (shapes, dims).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---------------------------------------------------------------
    // Serialization
    // ---------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        newline(out, d + 1);
                        item.write(out, Some(d + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(d) = indent {
                    if !v.is_empty() {
                        newline(out, d);
                    }
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        newline(out, d + 1);
                        write_escaped(out, k);
                        out.push_str(": ");
                        val.write(out, Some(d + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        val.write(out, None);
                    }
                }
                if let Some(d) = indent {
                    if !m.is_empty() {
                        newline(out, d);
                    }
                }
                out.push('}');
            }
        }
    }

    // Builders used by config/metrics dumps.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

fn newline(out: &mut String, depth: usize) {
    out.push('\n');
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(
                                ch.ok_or_else(|| self.err("bad \\u escape"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
        assert_eq!(Json::parse(r#""é😀""#).unwrap(), Json::Str("é😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-7}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), j);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 5, "s": [1,2,3]}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 5);
        assert_eq!(j.get("s").unwrap().as_usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(j.get("missing").is_err());
        assert!(j.get("n").unwrap().as_str().is_err());
        assert!(Json::Num(1.5).as_u64().is_err());
    }
}
