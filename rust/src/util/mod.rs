//! Small substrates the crate would normally pull from crates.io
//! (serde, rand, env_logger, humansize) but builds itself: the offline
//! vendor set ships only the XLA dependency tree.

pub mod fmtsize;
pub mod json;
pub mod logging;
pub mod retry;
pub mod rng;

pub use fmtsize::{fmt_bytes, fmt_duration};
pub use json::Json;
pub use rng::Rng;
