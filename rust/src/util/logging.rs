//! Tiny `log` backend (env_logger is not in the offline vendor set).
//!
//! Level comes from `HAPI_LOG` (error|warn|info|debug|trace), default
//! `info`.  Timestamps are seconds since logger init — good enough to read
//! event ordering in experiment logs.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

struct Logger {
    start: Instant,
}

impl log::Log for Logger {
    fn enabled(&self, _m: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{t:9.3} {lvl} {}] {}",
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<Logger> = OnceLock::new();

/// Install the logger (idempotent).
pub fn init() {
    let logger = LOGGER.get_or_init(|| Logger {
        start: Instant::now(),
    });
    let level = match std::env::var("HAPI_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    // set_logger fails if already set; that's fine (tests call init often).
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger alive");
    }
}
