//! Tiny std-only logger (neither `log` nor `env_logger` is in the
//! offline vendor set — the crate builds with zero dependencies).
//!
//! Level comes from `HAPI_LOG` (error|warn|info|debug|trace), default
//! `info`.  Timestamps are seconds since logger init — good enough to
//! read event ordering in experiment logs.  Call sites use the
//! `format_args!` helpers: `logging::debug("proxy", format_args!(...))`.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static START: OnceLock<Instant> = OnceLock::new();
/// 0 = uninitialised; otherwise a `Level` discriminant.
static MAX_LEVEL: AtomicU8 = AtomicU8::new(0);

/// Install the logger (idempotent).
pub fn init() {
    START.get_or_init(Instant::now);
    if MAX_LEVEL.load(Ordering::Relaxed) != 0 {
        return;
    }
    let level = match std::env::var("HAPI_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    let max = MAX_LEVEL.load(Ordering::Relaxed);
    // Logging before init() behaves like the default `info` level.
    let max = if max == 0 { Level::Info as u8 } else { max };
    level as u8 <= max
}

/// Emit one record; `target` is a short component name.
pub fn log(level: Level, target: &str, args: fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:9.3} {} {target}] {args}", level.tag());
}

pub fn error(target: &str, args: fmt::Arguments) {
    log(Level::Error, target, args)
}

pub fn warn(target: &str, args: fmt::Arguments) {
    log(Level::Warn, target, args)
}

pub fn info(target: &str, args: fmt::Arguments) {
    log(Level::Info, target, args)
}

pub fn debug(target: &str, args: fmt::Arguments) {
    log(Level::Debug, target, args)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        info("test", format_args!("logger alive"));
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        // At the default level, info is on and debug off (unless the
        // environment opts into debug/trace).
        init();
        if !matches!(
            std::env::var("HAPI_LOG").as_deref(),
            Ok("debug") | Ok("trace")
        ) {
            assert!(enabled(Level::Info));
            assert!(!enabled(Level::Trace));
        }
    }
}
