//! Deterministic PRNG (splitmix64 seeding + xoshiro256**).
//!
//! Used by the synthetic dataset generator, the workload generator, and
//! the property tests (the offline vendor set has no `rand`/`proptest`).
//! Deterministic by construction: same seed, same stream, every platform.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-tenant / per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`; unbiased via rejection sampling.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f32() + 1e-12).min(1.0);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.usize_below(i + 1));
        }
    }

    /// Pick a uniform element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
