//! A metered, optionally shaped, bidirectional link.
//!
//! One [`Link`] models a single network *path* between the compute tier
//! and a COS front end: a shared token bucket (both directions contend
//! for the same capacity, like a `tc` limited NIC) plus per-direction
//! byte counters.  The COS wire protocol calls
//! [`Link::send`]/[`Link::recv`] around every frame.
//!
//! A path link built by [`crate::netsim::Topology`] additionally
//! carries:
//!
//! - an optional **aggregate bucket** shared with every sibling path —
//!   the client-NIC cap: a byte must clear *both* its path's bucket and
//!   the aggregate before it counts as delivered;
//! - a shared **NIC meter** ([`LinkStats`]) that every path also
//!   charges, so the client can read total bytes moved without summing
//!   paths;
//! - an optional fixed per-frame **latency** (one-way propagation per
//!   direction), modeling a longer route to a remote proxy;
//! - an optional **queueing-delay model** (`path_queue_model` knob):
//!   per-frame latency grows with the path's recent utilisation —
//!   `latency × (1 + ρ/(1−ρ))`, the M/M/1 sojourn-over-service ratio
//!   with the configured `latency` as the constant service time and
//!   ρ the EWMA-measured offered load over the path's shaped rate
//!   (capped at [`RHO_MAX`] so the term stays finite at saturation).
//!   A loaded front end then *looks* loaded — fetch latency rises
//!   before the token bucket fully starves — which is what gives the
//!   client's hedger a realistic straggler signal and fig16c its
//!   sharper knee.  Needs both a shaped rate (ρ is load/rate) and a
//!   nonzero base `latency`; on an unshaped or zero-latency path the
//!   model is inert.
//!
//! The plain [`Link::shaped`]/[`Link::unshaped`] constructors carry
//! none of these — they behave exactly as the single-link model always
//! did.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::bucket::TokenBucket;

/// Shape bytes in chunks so concurrent streams interleave fairly (both
/// across connections on one path and across paths on the aggregate).
const CHUNK: u64 = 64 * 1024;

/// Averaging window for the queue model's offered-load EWMA, seconds.
const QUEUE_TAU: f64 = 0.25;

/// Utilisation cap for the M/M/1 term: ρ/(1−ρ) at 0.95 is a 19×
/// latency inflation — saturated, but finite and monotone.
const RHO_MAX: f64 = 0.95;

/// Exponentially-decayed byte meter behind the queueing-delay model:
/// `acc / QUEUE_TAU` approximates the bytes/sec recently offered to
/// the path.  A mutex is fine here — every user of this state is
/// about to sleep for the latency it computes.
struct QueueState {
    acc_bytes: f64,
    last: Instant,
}

#[derive(Debug, Default)]
pub struct LinkStats {
    /// Bytes client → COS (POST bodies, PUT uploads).
    pub tx: AtomicU64,
    /// Bytes COS → client (GET data, feature tensors).
    pub rx: AtomicU64,
}

impl LinkStats {
    pub fn tx_bytes(&self) -> u64 {
        self.tx.load(Ordering::Relaxed)
    }

    pub fn rx_bytes(&self) -> u64 {
        self.rx.load(Ordering::Relaxed)
    }

    pub fn total(&self) -> u64 {
        self.tx_bytes() + self.rx_bytes()
    }

    pub fn reset(&self) {
        self.tx.store(0, Ordering::Relaxed);
        self.rx.store(0, Ordering::Relaxed);
    }
}

#[derive(Clone)]
pub struct Link {
    bucket: Option<Arc<TokenBucket>>,
    /// Client-NIC cap shared with sibling paths (topology links only).
    aggregate: Option<Arc<TokenBucket>>,
    stats: Arc<LinkStats>,
    /// Shared NIC meter additionally charged by topology path links.
    nic_stats: Option<Arc<LinkStats>>,
    /// One-way propagation delay charged per frame per direction, in
    /// nanoseconds.  Atomic (and shared across clones) so jitter can be
    /// injected mid-run via [`Link::set_latency`] without tearing down
    /// the path.
    latency_ns: Arc<AtomicU64>,
    /// Utilisation meter for the queueing-delay model (`None` = the
    /// classic constant-latency behaviour).
    queue: Option<Arc<Mutex<QueueState>>>,
}

impl Link {
    /// Unshaped link (still metered).  Used for the proxy ↔ storage-node
    /// path, which the paper treats as a fast internal network.
    pub fn unshaped() -> Self {
        Link {
            bucket: None,
            aggregate: None,
            stats: Arc::new(LinkStats::default()),
            nic_stats: None,
            latency_ns: Arc::new(AtomicU64::new(0)),
            queue: None,
        }
    }

    /// Link limited to `rate` bytes/second.
    pub fn shaped(rate: u64) -> Self {
        Link {
            bucket: Some(Arc::new(TokenBucket::with_default_burst(rate))),
            aggregate: None,
            stats: Arc::new(LinkStats::default()),
            nic_stats: None,
            latency_ns: Arc::new(AtomicU64::new(0)),
            queue: None,
        }
    }

    /// One path of a multi-path topology: its own optional bucket, an
    /// optional aggregate (client-NIC) bucket shared with sibling
    /// paths, the shared NIC meter, a fixed per-frame latency, and
    /// optionally the utilisation-dependent queueing-delay model.
    pub(crate) fn path(
        rate: Option<u64>,
        latency: Duration,
        aggregate: Option<Arc<TokenBucket>>,
        nic_stats: Arc<LinkStats>,
        queue_model: bool,
    ) -> Self {
        Link {
            bucket: rate
                .map(|r| Arc::new(TokenBucket::with_default_burst(r))),
            aggregate,
            stats: Arc::new(LinkStats::default()),
            nic_stats: Some(nic_stats),
            latency_ns: Arc::new(AtomicU64::new(
                latency.as_nanos() as u64,
            )),
            queue: queue_model.then(|| {
                Arc::new(Mutex::new(QueueState {
                    acc_bytes: 0.0,
                    last: Instant::now(),
                }))
            }),
        }
    }

    /// Account + shape `n` bytes moving client → COS.
    pub fn send(&self, n: u64) {
        self.stats.tx.fetch_add(n, Ordering::Relaxed);
        if let Some(nic) = &self.nic_stats {
            nic.tx.fetch_add(n, Ordering::Relaxed);
        }
        self.delay(n);
        self.shape(n);
    }

    /// Account + shape `n` bytes moving COS → client.
    pub fn recv(&self, n: u64) {
        self.stats.rx.fetch_add(n, Ordering::Relaxed);
        if let Some(nic) = &self.nic_stats {
            nic.rx.fetch_add(n, Ordering::Relaxed);
        }
        self.delay(n);
        self.shape(n);
    }

    /// The path's current utilisation estimate ρ ∈ [0, RHO_MAX]:
    /// recently offered bytes/sec over the shaped rate, after folding
    /// this frame's `n` bytes in.  0 without the queue model, a shaped
    /// rate, or recent load.
    fn utilisation(&self, n: u64) -> f64 {
        let (Some(q), Some(rate)) = (&self.queue, self.rate()) else {
            return 0.0;
        };
        let mut s = q.lock().unwrap();
        let now = Instant::now();
        let dt = now.duration_since(s.last).as_secs_f64();
        s.acc_bytes = s.acc_bytes * (-dt / QUEUE_TAU).exp() + n as f64;
        s.last = now;
        ((s.acc_bytes / QUEUE_TAU) / rate.max(1) as f64).min(RHO_MAX)
    }

    /// Read-only variant of [`Link::utilisation`]: the current ρ with
    /// only decay applied — no bytes folded in, no meter state written.
    /// The planner's bounded-admission cap samples this (via
    /// [`crate::netsim::Topology::peak_utilisation`]) so an observer
    /// polling the signal never inflates the load it is measuring.
    pub fn utilisation_estimate(&self) -> f64 {
        let (Some(q), Some(rate)) = (&self.queue, self.rate()) else {
            return 0.0;
        };
        let s = q.lock().unwrap();
        let dt = Instant::now()
            .saturating_duration_since(s.last)
            .as_secs_f64();
        let acc = s.acc_bytes * (-dt / QUEUE_TAU).exp();
        ((acc / QUEUE_TAU) / rate.max(1) as f64).min(RHO_MAX)
    }

    fn delay(&self, n: u64) {
        let base = self.latency();
        if base.is_zero() {
            return;
        }
        let mut wait = base;
        if self.queue.is_some() {
            // M/M/1 sojourn over service: the constant `latency` is
            // the service time, the queueing term scales it by
            // ρ/(1−ρ) — monotone in utilisation, zero when idle
            // (pinned in `tests/netsim_props.rs`).
            let rho = self.utilisation(n);
            wait += base.mul_f64(rho / (1.0 - rho));
        }
        std::thread::sleep(wait);
    }

    fn shape(&self, n: u64) {
        if self.bucket.is_none() && self.aggregate.is_none() {
            return;
        }
        let mut left = n;
        while left > 0 {
            let take = left.min(CHUNK);
            if let Some(bucket) = &self.bucket {
                bucket.take(take);
            }
            if let Some(agg) = &self.aggregate {
                agg.take(take);
            }
            left -= take;
        }
    }

    pub fn stats(&self) -> &LinkStats {
        &self.stats
    }

    pub fn rate(&self) -> Option<u64> {
        self.bucket.as_ref().map(|b| b.rate())
    }

    /// Re-shape a shaped link mid-run (Table 4's bandwidth changes); a
    /// no-op on unshaped links.  All clones of this link see the new
    /// rate — they share the bucket, like flows behind one `tc` qdisc.
    /// On a topology path link this reshapes *only this path*; the
    /// shared aggregate cap is untouched.
    pub fn set_rate(&self, rate: u64) {
        if let Some(bucket) = &self.bucket {
            bucket.set_rate(rate);
        }
    }

    /// The link's current per-frame propagation delay.
    pub fn latency(&self) -> Duration {
        Duration::from_nanos(self.latency_ns.load(Ordering::Relaxed))
    }

    /// Change the per-frame propagation delay mid-run (latency jitter
    /// injection).  All clones see the new value — they share the
    /// counter.  Raising the latency also scales the queue model's
    /// service time; setting it to zero disables the delay (and the
    /// queue model, which needs a nonzero service time) entirely.
    pub fn set_latency(&self, latency: Duration) {
        self.latency_ns
            .store(latency.as_nanos() as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn meters_both_directions() {
        let link = Link::unshaped();
        link.send(100);
        link.recv(250);
        link.send(1);
        assert_eq!(link.stats().tx_bytes(), 101);
        assert_eq!(link.stats().rx_bytes(), 250);
        assert_eq!(link.stats().total(), 351);
        link.stats().reset();
        assert_eq!(link.stats().total(), 0);
    }

    #[test]
    fn shaped_link_slows_transfer() {
        let rate = 4 * 1024 * 1024; // 4 MiB/s
        let link = Link::shaped(rate);
        let start = Instant::now();
        link.recv(1024 * 1024); // 1 MiB beyond ~200 KiB burst
        let elapsed = start.elapsed().as_secs_f64();
        assert!(elapsed > 0.1, "elapsed {elapsed}");
    }

    #[test]
    fn set_rate_is_shared_across_clones() {
        let link = Link::shaped(100 * 1024 * 1024);
        let clone = link.clone();
        clone.set_rate(1234);
        assert_eq!(link.rate(), Some(1234));
        // Unshaped links ignore it.
        let un = Link::unshaped();
        un.set_rate(99);
        assert_eq!(un.rate(), None);
    }

    #[test]
    fn utilisation_estimate_reads_without_inflating() {
        // Queue-modeled path with a shaped rate and zero latency (the
        // delay itself is inert, only the meter matters here).
        let nic = Arc::new(LinkStats::default());
        let link = Link::path(
            Some(100 * 1024 * 1024),
            Duration::ZERO,
            None,
            nic,
            true,
        );
        assert_eq!(link.utilisation_estimate(), 0.0);
        link.recv(8 * 1024 * 1024);
        let rho = link.utilisation_estimate();
        assert!(rho > 0.0, "load should register: ρ = {rho}");
        assert!(rho <= RHO_MAX);
        // Polling is read-only: back-to-back estimates never grow.
        assert!(link.utilisation_estimate() <= rho);

        // No queue model (or no shaped rate) → no signal.
        assert_eq!(Link::unshaped().utilisation_estimate(), 0.0);
        assert_eq!(
            Link::shaped(1024).utilisation_estimate(),
            0.0,
            "plain shaped link carries no queue meter"
        );
    }

    #[test]
    fn unshaped_is_instant() {
        let link = Link::unshaped();
        let start = Instant::now();
        link.recv(1 << 30);
        assert!(start.elapsed().as_millis() < 50);
    }

    #[test]
    fn path_link_charges_nic_meter_and_aggregate() {
        let nic = Arc::new(LinkStats::default());
        // Path unshaped, aggregate capped: the aggregate is the only
        // thing slowing the transfer.
        let agg =
            Arc::new(TokenBucket::new(4 * 1024 * 1024, 64 * 1024));
        let link = Link::path(
            None,
            Duration::ZERO,
            Some(agg),
            nic.clone(),
            false,
        );
        let start = Instant::now();
        link.recv(1024 * 1024);
        assert!(
            start.elapsed().as_secs_f64() > 0.1,
            "aggregate cap must bind on an unshaped path"
        );
        assert_eq!(link.stats().rx_bytes(), 1024 * 1024);
        assert_eq!(nic.rx_bytes(), 1024 * 1024);
    }

    #[test]
    fn set_latency_is_shared_across_clones() {
        let nic = Arc::new(LinkStats::default());
        let link =
            Link::path(None, Duration::from_millis(1), None, nic, false);
        let clone = link.clone();
        clone.set_latency(Duration::from_millis(30));
        assert_eq!(link.latency(), Duration::from_millis(30));
        let start = Instant::now();
        link.recv(10);
        assert!(
            start.elapsed() >= Duration::from_millis(25),
            "frame must pay the jittered latency: {:?}",
            start.elapsed()
        );
        // Zeroing the latency turns the delay off entirely.
        link.set_latency(Duration::ZERO);
        let start = Instant::now();
        link.recv(10);
        assert!(start.elapsed() < Duration::from_millis(20));
    }

    #[test]
    fn path_latency_is_charged_per_frame() {
        let nic = Arc::new(LinkStats::default());
        let link =
            Link::path(None, Duration::from_millis(20), None, nic, false);
        let start = Instant::now();
        link.send(10);
        link.recv(10);
        assert!(
            start.elapsed() >= Duration::from_millis(35),
            "two frames must pay two propagation delays: {:?}",
            start.elapsed()
        );
    }
}
