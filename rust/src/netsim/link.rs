//! A metered, optionally shaped, bidirectional link.
//!
//! One [`Link`] models the compute-tier ↔ COS network: a shared token
//! bucket (both directions contend for the same capacity, like a `tc`
//! limited NIC) plus per-direction byte counters.  The COS wire protocol
//! calls [`Link::send`]/[`Link::recv`] around every frame.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::bucket::TokenBucket;

/// Shape bytes in chunks so concurrent streams interleave fairly.
const CHUNK: u64 = 64 * 1024;

#[derive(Debug, Default)]
pub struct LinkStats {
    /// Bytes client → COS (POST bodies, PUT uploads).
    pub tx: AtomicU64,
    /// Bytes COS → client (GET data, feature tensors).
    pub rx: AtomicU64,
}

impl LinkStats {
    pub fn tx_bytes(&self) -> u64 {
        self.tx.load(Ordering::Relaxed)
    }

    pub fn rx_bytes(&self) -> u64 {
        self.rx.load(Ordering::Relaxed)
    }

    pub fn total(&self) -> u64 {
        self.tx_bytes() + self.rx_bytes()
    }

    pub fn reset(&self) {
        self.tx.store(0, Ordering::Relaxed);
        self.rx.store(0, Ordering::Relaxed);
    }
}

#[derive(Clone)]
pub struct Link {
    bucket: Option<Arc<TokenBucket>>,
    stats: Arc<LinkStats>,
}

impl Link {
    /// Unshaped link (still metered).  Used for the proxy ↔ storage-node
    /// path, which the paper treats as a fast internal network.
    pub fn unshaped() -> Self {
        Link {
            bucket: None,
            stats: Arc::new(LinkStats::default()),
        }
    }

    /// Link limited to `rate` bytes/second.
    pub fn shaped(rate: u64) -> Self {
        Link {
            bucket: Some(Arc::new(TokenBucket::with_default_burst(rate))),
            stats: Arc::new(LinkStats::default()),
        }
    }

    /// Account + shape `n` bytes moving client → COS.
    pub fn send(&self, n: u64) {
        self.stats.tx.fetch_add(n, Ordering::Relaxed);
        self.shape(n);
    }

    /// Account + shape `n` bytes moving COS → client.
    pub fn recv(&self, n: u64) {
        self.stats.rx.fetch_add(n, Ordering::Relaxed);
        self.shape(n);
    }

    fn shape(&self, n: u64) {
        if let Some(bucket) = &self.bucket {
            let mut left = n;
            while left > 0 {
                let take = left.min(CHUNK);
                bucket.take(take);
                left -= take;
            }
        }
    }

    pub fn stats(&self) -> &LinkStats {
        &self.stats
    }

    pub fn rate(&self) -> Option<u64> {
        self.bucket.as_ref().map(|b| b.rate())
    }

    /// Re-shape a shaped link mid-run (Table 4's bandwidth changes); a
    /// no-op on unshaped links.  All clones of this link see the new
    /// rate — they share the bucket, like flows behind one `tc` qdisc.
    pub fn set_rate(&self, rate: u64) {
        if let Some(bucket) = &self.bucket {
            bucket.set_rate(rate);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn meters_both_directions() {
        let link = Link::unshaped();
        link.send(100);
        link.recv(250);
        link.send(1);
        assert_eq!(link.stats().tx_bytes(), 101);
        assert_eq!(link.stats().rx_bytes(), 250);
        assert_eq!(link.stats().total(), 351);
        link.stats().reset();
        assert_eq!(link.stats().total(), 0);
    }

    #[test]
    fn shaped_link_slows_transfer() {
        let rate = 4 * 1024 * 1024; // 4 MiB/s
        let link = Link::shaped(rate);
        let start = Instant::now();
        link.recv(1024 * 1024); // 1 MiB beyond ~200 KiB burst
        let elapsed = start.elapsed().as_secs_f64();
        assert!(elapsed > 0.1, "elapsed {elapsed}");
    }

    #[test]
    fn set_rate_is_shared_across_clones() {
        let link = Link::shaped(100 * 1024 * 1024);
        let clone = link.clone();
        clone.set_rate(1234);
        assert_eq!(link.rate(), Some(1234));
        // Unshaped links ignore it.
        let un = Link::unshaped();
        un.set_rate(99);
        assert_eq!(un.rate(), None);
    }

    #[test]
    fn unshaped_is_instant() {
        let link = Link::unshaped();
        let start = Instant::now();
        link.recv(1 << 30);
        assert!(start.elapsed().as_millis() < 50);
    }
}
