//! Path-aware network topology: the multi-NIC / multi-proxy model.
//!
//! The paper's testbed reads from S3-style object stores through many
//! parallel front-end servers; the storage network is a *parallel*
//! resource, not one pipe.  A [`Topology`] models that: `N` named paths
//! (client-NIC → proxy-`i`), each shaped by its own [`TokenBucket`] and
//! charged a per-path propagation latency, plus an optional **aggregate
//! client-NIC cap** that every byte must clear too — so fanning
//! connections over paths scales throughput with the path count until
//! the NIC cap binds, exactly the fig16 multi-path claim.
//!
//! ```text
//!              ┌─ path 0 (rate r0, lat l0) ── proxy 0 ─┐
//!  client NIC ─┼─ path 1 (rate r1, lat l1) ── proxy 1 ─┼─ COS cluster
//!   (agg cap)  └─ path N-1 ( … )           ── proxy N-1┘
//! ```
//!
//! A one-path topology with no aggregate cap and zero latency is
//! byte-for-byte the old single-`Link` model — the default config
//! reproduces every pre-topology result unchanged.
//!
//! Cheap to clone; clones share every bucket and meter.

use std::sync::Arc;
use std::time::Duration;

use super::bucket::TokenBucket;
use super::link::{Link, LinkStats};

/// One path's shape: its dedicated rate (`None` = unshaped), a fixed
/// one-way propagation delay charged per frame per direction, and
/// whether the per-frame delay grows with the path's utilisation (the
/// M/M/1-style queueing model — see [`super::link`]; it needs both a
/// shaped rate and a nonzero latency to have any effect).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PathSpec {
    pub rate: Option<u64>,
    pub latency: Duration,
    pub queue_model: bool,
}

impl PathSpec {
    pub fn shaped(rate: u64) -> PathSpec {
        PathSpec {
            rate: Some(rate),
            latency: Duration::ZERO,
            queue_model: false,
        }
    }

    pub fn unshaped() -> PathSpec {
        PathSpec {
            rate: None,
            latency: Duration::ZERO,
            queue_model: false,
        }
    }
}

/// Full topology shape: the per-path specs plus the optional shared
/// client-NIC aggregate cap (bytes/sec across *all* paths).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopologySpec {
    pub paths: Vec<PathSpec>,
    pub aggregate_rate: Option<u64>,
}

impl TopologySpec {
    /// The classic single-link model: one path, no NIC cap.
    pub fn single(rate: Option<u64>) -> TopologySpec {
        TopologySpec {
            paths: vec![PathSpec {
                rate,
                latency: Duration::ZERO,
                queue_model: false,
            }],
            aggregate_rate: None,
        }
    }
}

#[derive(Clone)]
pub struct Topology {
    paths: Arc<Vec<Link>>,
    /// Shared NIC meter: every path's bytes also land here.
    nic_stats: Arc<LinkStats>,
    aggregate: Option<Arc<TokenBucket>>,
}

impl Topology {
    pub fn new(spec: &TopologySpec) -> Topology {
        assert!(!spec.paths.is_empty(), "topology needs >= 1 path");
        let aggregate = spec
            .aggregate_rate
            .map(|r| Arc::new(TokenBucket::with_default_burst(r)));
        let nic_stats = Arc::new(LinkStats::default());
        let paths = spec
            .paths
            .iter()
            .map(|p| {
                Link::path(
                    p.rate,
                    p.latency,
                    aggregate.clone(),
                    nic_stats.clone(),
                    p.queue_model,
                )
            })
            .collect();
        Topology {
            paths: Arc::new(paths),
            nic_stats,
            aggregate,
        }
    }

    /// One path at `rate` (`None` = unshaped), no cap, zero latency —
    /// the drop-in replacement for the old single `Link`.
    pub fn single(rate: Option<u64>) -> Topology {
        Topology::new(&TopologySpec::single(rate))
    }

    pub fn num_paths(&self) -> usize {
        self.paths.len()
    }

    /// The `i`-th path's link (connection pools pin one slot to one
    /// path and charge exactly this link).
    pub fn path(&self, i: usize) -> &Link {
        &self.paths[i]
    }

    /// Aggregate NIC meter: total bytes moved across every path.
    pub fn stats(&self) -> &LinkStats {
        &self.nic_stats
    }

    /// The capacity a split decision should assume: the sum of shaped
    /// path rates, clamped by the aggregate cap.  `None` when the
    /// effective capacity is unbounded (an unshaped path and no cap).
    pub fn total_rate(&self) -> Option<u64> {
        let agg = self.aggregate.as_ref().map(|b| b.rate());
        let mut sum: u64 = 0;
        for p in self.paths.iter() {
            match p.rate() {
                Some(r) => sum = sum.saturating_add(r),
                None => return agg,
            }
        }
        Some(match agg {
            Some(a) => a.min(sum),
            None => sum,
        })
    }

    /// The most-loaded path's utilisation estimate ρ ∈ [0, RHO_MAX]
    /// (read-only; see [`Link::utilisation_estimate`]).  0 everywhere
    /// the queue model is off — the planner's bounded-admission cap
    /// polls this as its server-visible queueing signal, and a zero
    /// signal leaves the cap at its configured value.
    pub fn peak_utilisation(&self) -> f64 {
        self.paths
            .iter()
            .map(Link::utilisation_estimate)
            .fold(0.0, f64::max)
    }

    /// The shared client-NIC cap, if one is configured.
    pub fn aggregate_rate(&self) -> Option<u64> {
        self.aggregate.as_ref().map(|b| b.rate())
    }

    /// Re-shape one path mid-run (the per-path `tc` change: one COS
    /// front end degrades while its siblings stay healthy).  Sibling
    /// paths and the aggregate cap are untouched.
    ///
    /// Like [`Link::set_rate`], this is a **no-op on an unshaped
    /// path** (`rate: None` / `path_rates_mbps: 0`): an unshaped path
    /// has no bucket to reshape, so a degradation experiment must
    /// start from a shaped one — check [`Topology::path`]`.rate()` is
    /// `Some` if in doubt.
    pub fn set_path_rate(&self, path: usize, rate: u64) {
        self.paths[path].set_rate(rate);
    }

    /// Inject latency jitter on one path mid-run: replace its per-frame
    /// propagation delay (a longer route after a failover, a loaded
    /// front end).  Unlike [`Topology::set_path_rate`] this works on
    /// every path — the latency counter always exists, even when the
    /// path was built with zero latency.  With `path_queue_model` on,
    /// the new value also becomes the queue model's service time.
    pub fn set_path_latency(&self, path: usize, latency: Duration) {
        self.paths[path].set_latency(latency);
    }

    /// The `path`-th path's current per-frame propagation delay.
    pub fn path_latency(&self, path: usize) -> Duration {
        self.paths[path].latency()
    }

    /// Re-shape *every* path to `rate` — on a one-path topology this is
    /// exactly the old `Link::set_rate` whole-link change.  Unshaped
    /// paths are skipped (no bucket to reshape), same as
    /// [`Link::set_rate`]; the aggregate cap is untouched.
    pub fn set_rate(&self, rate: u64) {
        for p in self.paths.iter() {
            p.set_rate(rate);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn peak_utilisation_tracks_the_loaded_path() {
        let spec = TopologySpec {
            paths: vec![
                PathSpec {
                    rate: Some(100 * 1024 * 1024),
                    latency: Duration::ZERO,
                    queue_model: true,
                },
                PathSpec {
                    rate: Some(100 * 1024 * 1024),
                    latency: Duration::ZERO,
                    queue_model: true,
                },
            ],
            aggregate_rate: None,
        };
        let net = Topology::new(&spec);
        assert_eq!(net.peak_utilisation(), 0.0);
        net.path(1).recv(8 * 1024 * 1024);
        assert!(net.peak_utilisation() > 0.0);

        // The classic single link carries no queue meter → no signal.
        let single = Topology::single(Some(1024 * 1024));
        single.path(0).recv(4096);
        assert_eq!(single.peak_utilisation(), 0.0);
    }

    #[test]
    fn single_path_behaves_like_the_old_link() {
        let t = Topology::single(Some(4 * 1024 * 1024));
        assert_eq!(t.num_paths(), 1);
        assert_eq!(t.total_rate(), Some(4 * 1024 * 1024));
        assert_eq!(t.aggregate_rate(), None);
        let start = Instant::now();
        t.path(0).recv(1024 * 1024);
        assert!(start.elapsed().as_secs_f64() > 0.1);
        // Whole-topology set_rate == the old whole-link set_rate.
        t.set_rate(1111);
        assert_eq!(t.path(0).rate(), Some(1111));
        assert_eq!(t.total_rate(), Some(1111));
        // The NIC meter saw the path's bytes.
        assert_eq!(t.stats().rx_bytes(), 1024 * 1024);
    }

    #[test]
    fn total_rate_sums_paths_and_clamps_to_aggregate() {
        let spec = TopologySpec {
            paths: vec![PathSpec::shaped(100), PathSpec::shaped(50)],
            aggregate_rate: None,
        };
        assert_eq!(Topology::new(&spec).total_rate(), Some(150));

        let spec = TopologySpec {
            paths: vec![PathSpec::shaped(100), PathSpec::shaped(50)],
            aggregate_rate: Some(120),
        };
        assert_eq!(Topology::new(&spec).total_rate(), Some(120));

        // An unshaped path falls through to the cap (or unbounded).
        let spec = TopologySpec {
            paths: vec![PathSpec::unshaped(), PathSpec::shaped(50)],
            aggregate_rate: Some(99),
        };
        assert_eq!(Topology::new(&spec).total_rate(), Some(99));
        let spec = TopologySpec {
            paths: vec![PathSpec::unshaped()],
            aggregate_rate: None,
        };
        assert_eq!(Topology::new(&spec).total_rate(), None);
    }

    #[test]
    fn per_path_reshape_leaves_siblings_alone() {
        let spec = TopologySpec {
            paths: vec![PathSpec::shaped(1000), PathSpec::shaped(1000)],
            aggregate_rate: None,
        };
        let t = Topology::new(&spec);
        t.set_path_rate(0, 10);
        assert_eq!(t.path(0).rate(), Some(10));
        assert_eq!(t.path(1).rate(), Some(1000));
        assert_eq!(t.total_rate(), Some(1010));
    }

    #[test]
    fn per_path_latency_jitter_is_injectable_mid_run() {
        let spec = TopologySpec {
            paths: vec![PathSpec::unshaped(), PathSpec::unshaped()],
            aggregate_rate: None,
        };
        let t = Topology::new(&spec);
        assert_eq!(t.path_latency(0), Duration::ZERO);
        t.set_path_latency(0, Duration::from_millis(25));
        assert_eq!(t.path_latency(0), Duration::from_millis(25));
        // The sibling keeps its own (zero) latency.
        assert_eq!(t.path_latency(1), Duration::ZERO);
        let start = Instant::now();
        t.path(0).recv(10);
        assert!(start.elapsed() >= Duration::from_millis(20));
        let start = Instant::now();
        t.path(1).recv(10);
        assert!(start.elapsed() < Duration::from_millis(20));
    }

    #[test]
    fn nic_meter_aggregates_all_paths() {
        let spec = TopologySpec {
            paths: vec![PathSpec::unshaped(), PathSpec::unshaped()],
            aggregate_rate: None,
        };
        let t = Topology::new(&spec);
        t.path(0).send(10);
        t.path(1).send(5);
        t.path(1).recv(70);
        assert_eq!(t.path(0).stats().tx_bytes(), 10);
        assert_eq!(t.path(1).stats().tx_bytes(), 5);
        assert_eq!(t.stats().tx_bytes(), 15);
        assert_eq!(t.stats().rx_bytes(), 70);
    }
}
