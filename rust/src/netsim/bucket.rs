//! Blocking token bucket.
//!
//! `take(n)` debits `n` tokens (bytes), sleeping until the continuous
//! refill covers the deficit.  The bucket admits bursts up to `burst`
//! tokens, so short messages pass at line rate while the long-run average
//! converges to `rate` — the same behaviour as a `tc tbf` qdisc.

use std::sync::Mutex;
use std::time::{Duration, Instant};

pub struct TokenBucket {
    state: Mutex<State>,
    rate: f64,  // tokens (bytes) per second
    burst: f64, // bucket depth
}

struct State {
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// `rate` in bytes/sec. `burst` caps instantaneous debt; a good default
    /// is ~50 ms worth of line rate.
    pub fn new(rate: u64, burst: u64) -> Self {
        assert!(rate > 0);
        TokenBucket {
            state: Mutex::new(State {
                tokens: burst as f64,
                last: Instant::now(),
            }),
            rate: rate as f64,
            burst: burst.max(1) as f64,
        }
    }

    /// Bucket with a burst of 50 ms at line rate (min 64 KiB).
    pub fn with_default_burst(rate: u64) -> Self {
        let burst = ((rate as f64) * 0.05) as u64;
        TokenBucket::new(rate, burst.max(64 * 1024))
    }

    /// Debit `n` bytes, blocking as needed.  Large `n` are fine: the call
    /// sleeps exactly the deficit, it does not busy-wait.
    pub fn take(&self, n: u64) {
        let wait = {
            let mut s = self.state.lock().unwrap();
            let now = Instant::now();
            let elapsed = now.duration_since(s.last).as_secs_f64();
            s.tokens = (s.tokens + elapsed * self.rate).min(self.burst);
            s.last = now;
            s.tokens -= n as f64;
            if s.tokens >= 0.0 {
                Duration::ZERO
            } else {
                Duration::from_secs_f64(-s.tokens / self.rate)
            }
        };
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
    }

    pub fn rate(&self) -> u64 {
        self.rate as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn long_run_rate_is_respected() {
        // 10 MiB/s, send 2 MiB beyond the burst -> ≥ ~0.2s minus burst.
        let rate = 10 * 1024 * 1024;
        let bucket = TokenBucket::new(rate, 64 * 1024);
        let start = Instant::now();
        let total: u64 = 2 * 1024 * 1024;
        let mut sent = 0;
        while sent < total {
            let chunk = 64 * 1024;
            bucket.take(chunk);
            sent += chunk;
        }
        let elapsed = start.elapsed().as_secs_f64();
        let expected = (total - 64 * 1024) as f64 / rate as f64;
        assert!(
            elapsed >= expected * 0.85,
            "elapsed {elapsed:.3}s expected >= {expected:.3}s"
        );
        // And not pathologically slow either (3x margin for CI noise).
        assert!(elapsed < expected * 3.0 + 0.2, "elapsed {elapsed:.3}s");
    }

    #[test]
    fn burst_passes_without_sleep() {
        let bucket = TokenBucket::new(1024, 1024 * 1024);
        let start = Instant::now();
        bucket.take(512 * 1024); // within burst
        assert!(start.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn concurrent_takers_share_rate() {
        use std::sync::Arc;
        let rate = 8 * 1024 * 1024;
        let bucket = Arc::new(TokenBucket::new(rate, 32 * 1024));
        let start = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let b = bucket.clone();
                std::thread::spawn(move || {
                    for _ in 0..8 {
                        b.take(64 * 1024);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 4 threads x 512 KiB = 2 MiB at 8 MiB/s ≈ 0.25s.
        let elapsed = start.elapsed().as_secs_f64();
        assert!(elapsed > 0.15, "elapsed {elapsed:.3}");
    }
}
