//! Blocking token bucket.
//!
//! `take(n)` debits `n` tokens (bytes), sleeping until the continuous
//! refill covers the deficit.  The bucket admits bursts up to `burst`
//! tokens, so short messages pass at line rate while the long-run average
//! converges to `rate` — the same behaviour as a `tc tbf` qdisc.

use std::sync::Mutex;
use std::time::{Duration, Instant};

pub struct TokenBucket {
    state: Mutex<State>,
}

struct State {
    tokens: f64,
    last: Instant,
    rate: f64,  // tokens (bytes) per second
    burst: f64, // bucket depth
}

impl TokenBucket {
    /// `rate` in bytes/sec. `burst` caps instantaneous debt; a good default
    /// is ~50 ms worth of line rate.
    pub fn new(rate: u64, burst: u64) -> Self {
        assert!(rate > 0);
        TokenBucket {
            state: Mutex::new(State {
                tokens: burst as f64,
                last: Instant::now(),
                rate: rate as f64,
                burst: burst.max(1) as f64,
            }),
        }
    }

    /// Bucket with a burst of 50 ms at line rate (min 64 KiB).
    pub fn with_default_burst(rate: u64) -> Self {
        let burst = ((rate as f64) * 0.05) as u64;
        TokenBucket::new(rate, burst.max(64 * 1024))
    }

    /// Debit `n` bytes, blocking as needed.  Large `n` are fine: the call
    /// sleeps exactly the deficit, it does not busy-wait.
    pub fn take(&self, n: u64) {
        let wait = {
            let mut s = self.state.lock().unwrap();
            let now = Instant::now();
            let elapsed = now.duration_since(s.last).as_secs_f64();
            s.tokens = (s.tokens + elapsed * s.rate).min(s.burst);
            s.last = now;
            s.tokens -= n as f64;
            if s.tokens >= 0.0 {
                Duration::ZERO
            } else {
                Duration::from_secs_f64(-s.tokens / s.rate)
            }
        };
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
    }

    pub fn rate(&self) -> u64 {
        self.state.lock().unwrap().rate as u64
    }

    /// Re-shape the bucket mid-run (the paper's `tc` rate changes in
    /// §7.4 / Table 4).  The burst shrinks/grows to ~50 ms of the new
    /// line rate and accumulated credit is clamped so an old fast-rate
    /// burst cannot leak through the new slow rate.
    ///
    /// The burst floor here is deliberately 1 KiB, tighter than
    /// [`TokenBucket::with_default_burst`]'s 64 KiB cold-start floor: a
    /// re-shaped link is already hot, and granting it a fresh 64 KiB of
    /// credit would let transfers ride the *old* rate's burst for a
    /// while, masking the very rate change the experiment (and the
    /// client's per-window bandwidth re-measurement) is meant to
    /// observe.  A link *constructed* at the low rate keeps the larger
    /// cold-start burst, so the two are intentionally not like-for-like
    /// in their first ~64 KiB.
    pub fn set_rate(&self, rate: u64) {
        assert!(rate > 0);
        let mut s = self.state.lock().unwrap();
        // Settle the refill at the old rate up to now, then switch.
        let now = Instant::now();
        let elapsed = now.duration_since(s.last).as_secs_f64();
        s.tokens = (s.tokens + elapsed * s.rate).min(s.burst);
        s.last = now;
        s.rate = rate as f64;
        s.burst = ((rate as f64) * 0.05).max(1024.0);
        s.tokens = s.tokens.min(s.burst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn long_run_rate_is_respected() {
        // 10 MiB/s, send 2 MiB beyond the burst -> ≥ ~0.2s minus burst.
        let rate = 10 * 1024 * 1024;
        let bucket = TokenBucket::new(rate, 64 * 1024);
        let start = Instant::now();
        let total: u64 = 2 * 1024 * 1024;
        let mut sent = 0;
        while sent < total {
            let chunk = 64 * 1024;
            bucket.take(chunk);
            sent += chunk;
        }
        let elapsed = start.elapsed().as_secs_f64();
        let expected = (total - 64 * 1024) as f64 / rate as f64;
        assert!(
            elapsed >= expected * 0.85,
            "elapsed {elapsed:.3}s expected >= {expected:.3}s"
        );
        // And not pathologically slow either (3x margin for CI noise).
        assert!(elapsed < expected * 3.0 + 0.2, "elapsed {elapsed:.3}s");
    }

    #[test]
    fn burst_passes_without_sleep() {
        let bucket = TokenBucket::new(1024, 1024 * 1024);
        let start = Instant::now();
        bucket.take(512 * 1024); // within burst
        assert!(start.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn set_rate_takes_effect_and_clamps_burst() {
        let bucket = TokenBucket::new(100 * 1024 * 1024, 1024 * 1024);
        bucket.take(64 * 1024); // within burst, instant
        bucket.set_rate(64 * 1024); // 64 KiB/s
        assert_eq!(bucket.rate(), 64 * 1024);
        // The old 1 MiB burst must not leak through: 64 KiB now costs
        // about a second.
        let start = Instant::now();
        bucket.take(64 * 1024);
        assert!(
            start.elapsed() >= Duration::from_millis(500),
            "old burst leaked: {:?}",
            start.elapsed()
        );
    }

    #[test]
    fn concurrent_takers_share_rate() {
        use std::sync::Arc;
        let rate = 8 * 1024 * 1024;
        let bucket = Arc::new(TokenBucket::new(rate, 32 * 1024));
        let start = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let b = bucket.clone();
                std::thread::spawn(move || {
                    for _ in 0..8 {
                        b.take(64 * 1024);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 4 threads x 512 KiB = 2 MiB at 8 MiB/s ≈ 0.25s.
        let elapsed = start.elapsed().as_secs_f64();
        assert!(elapsed > 0.15, "elapsed {elapsed:.3}");
    }
}
