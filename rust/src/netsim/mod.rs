//! Network substrate: token-bucket bandwidth shaping + byte metering.
//!
//! The paper rate-limits the compute-tier ↔ COS link with `tc` (50 Mbps to
//! 12 Gbps, §7.4).  We reproduce that with a token bucket applied to every
//! byte crossing the link, plus exact per-direction byte meters that back
//! the "data transferred" axes of Figs 11b and 13.
//!
//! [`Topology`] generalises the single link to a set of per-path token
//! buckets (multi-NIC / multi-proxy) under an optional shared client-NIC
//! aggregate cap — the model behind the fig16 multi-path
//! aggregate-bandwidth scaling.

pub mod bucket;
pub mod link;
pub mod topology;

pub use bucket::TokenBucket;
pub use link::{Link, LinkStats};
pub use topology::{PathSpec, Topology, TopologySpec};

/// Convenience: Gbps → bytes/second.
pub fn gbps(g: f64) -> u64 {
    (g * 1e9 / 8.0) as u64
}

/// Convenience: Mbps → bytes/second.
pub fn mbps(m: f64) -> u64 {
    (m * 1e6 / 8.0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_conversions() {
        assert_eq!(gbps(1.0), 125_000_000);
        assert_eq!(mbps(150.0), 18_750_000);
    }
}
