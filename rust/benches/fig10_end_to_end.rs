//! Fig 10 — end-to-end epoch time: Hapi vs BASELINE for all seven
//! Table-1 models, strong (GPU) and weak (CPU) clients, training batches
//! 20 and 80 (paper: 2000/8000 at 1:10 of the 1:10 scale — one
//! iteration per epoch keeps the bench under control; relative shapes
//! are batch-size invariant).
//!
//! Expected shape: BASELINE marked X (OOM) for the large models at the
//! big batch; Hapi never OOMs; CPU clients favour Hapi strongly; larger
//! batches favour Hapi.

#[path = "common.rs"]
mod common;

use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::model::TABLE1_MODELS;
use hapi::runtime::DeviceKind;

fn run_case(
    model: &str,
    device: DeviceKind,
    batch: usize,
    baseline: bool,
) -> Result<f64, String> {
    let mut cfg = common::bench_config();
    // Paper default: 1 Gbps; testbed equivalent (same comm/comp balance
    // for the BASELINE): 2 Mbps.  See EXPERIMENTS.md §Calibration.
    cfg.bandwidth = Some(hapi::netsim::mbps(2.0));
    cfg.train_batch = batch;
    let bed = Testbed::launch(cfg).map_err(|e| e.to_string())?;
    let (ds, labels) =
        bed.dataset("f10", model, batch).map_err(|e| e.to_string())?;
    let client = if baseline {
        bed.baseline_client(model, device)
    } else {
        bed.hapi_client(model, device)
    }
    .map_err(|e| e.to_string())?;
    let t0 = std::time::Instant::now();
    let out = client.train_epoch(&ds, &labels);
    let secs = t0.elapsed().as_secs_f64();
    bed.stop();
    match out {
        Ok(_) => Ok(secs),
        Err(e) if e.is_oom() => Err("X (OOM)".into()),
        Err(e) => Err(format!("error: {e}")),
    }
}

fn main() {
    println!("== Fig 10: end-to-end, Hapi vs BASELINE ==\n");
    // (device, batch): GPU at both batches; CPU at the small batch only
    // (the weak-client story is batch-size independent).
    let cases = [
        (DeviceKind::Gpu, common::scaled(2000)),
        (DeviceKind::Gpu, common::scaled(8000)),
        (DeviceKind::Cpu, common::scaled(2000)),
    ];
    for (device, batch) in cases {
        let mut t = Table::new(
            &format!("{device:?} client, train batch {batch}"),
            &["model", "BASELINE (s)", "Hapi (s)", "speedup"],
        );
        let mut hapi_wins = 0usize;
        let mut comparable = 0usize;
        // Weak-client rows use three representative families (conv-heavy,
        // residual, attention): the CPU/GPU story is model-shape driven
        // and the full 7-model sweep triples the bench time.
        let models: &[&str] = if device == DeviceKind::Cpu {
            &["alexnet", "resnet18", "transformer"]
        } else {
            &TABLE1_MODELS
        };
        for &model in models {
            let base = run_case(model, device, batch, true);
            let hapi = run_case(model, device, batch, false);
            let fmt = |r: &Result<f64, String>| match r {
                Ok(s) => format!("{s:.1}"),
                Err(m) => m.clone(),
            };
            let speedup = match (&base, &hapi) {
                (Ok(b), Ok(h)) => {
                    comparable += 1;
                    if h <= b {
                        hapi_wins += 1;
                    }
                    format!("{:.2}x", b / h)
                }
                (Err(_), Ok(_)) => {
                    hapi_wins += 1;
                    "inf (baseline OOM)".into()
                }
                _ => "-".into(),
            };
            t.row(vec![
                model.to_string(),
                fmt(&base),
                fmt(&hapi),
                speedup,
            ]);
            assert!(
                hapi.is_ok(),
                "{model}@{device:?} b={batch}: Hapi must never fail ({hapi:?})"
            );
        }
        t.print();
        println!(
            "hapi wins or survives: {hapi_wins} (of {comparable} comparable)\n"
        );
    }
}
