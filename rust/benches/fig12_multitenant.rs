//! Fig 12 — scalability with multiple tenants vs ALL_IN_COS.
//!
//! N tenants (2, 6, 10) submit one job each at t=0, models round-robin
//! over Table 1 (§7.5) — or over the built-in sim profiles on a fresh
//! clone — training batch 100 (paper: 1000).  Reports makespan and
//! average JCT for Hapi and ALL_IN_COS.
//!
//! Expected shape: comparable at few tenants; ALL_IN_COS falls behind as
//! tenants grow (no batch decoupling: each job occupies the COS at the
//! training batch size and jobs serialise).
//!
//! A second section exercises the planner's per-client gather lanes: a
//! burst-1 tenant's time-to-grant (its lane's gather window) must stay
//! ~zero no matter how deep a co-tenant pipelines (`depth × shards`),
//! because each client gathers in its own lane — the cross-tenant
//! head-of-line-blocking fix.

#[path = "common.rs"]
mod common;

use hapi::cli::Args;
use hapi::config::BackendKind;
use hapi::harness::Testbed;
use hapi::metrics::{names, Table};
use hapi::runtime::DeviceKind;
use hapi::util::fmt_duration;
use hapi::workload::{run_tenants_with, tenant_model_for};

fn main() {
    let args = Args::from_env().expect("args");
    // `--planner-scale N`: run only the planner-scale sweep at N
    // tenants (the CI smoke; the full bench sweeps 100 → 1000).
    let scale_only: usize = args.parse_or("planner-scale", 0).expect(
        "--planner-scale takes a tenant count",
    );
    if scale_only > 0 {
        planner_scale_sweep(&[scale_only]);
        return;
    }
    println!("== Fig 12: multi-tenant scalability ==\n");
    let hlo = common::bench_config_or_sim().backend == BackendKind::Hlo;
    let mut t = Table::new(
        "Hapi vs ALL_IN_COS",
        &[
            "tenants",
            "Hapi makespan",
            "Hapi avg JCT",
            "AIC makespan",
            "AIC avg JCT",
            "JCT ratio",
        ],
    );
    let mut ratios = Vec::new();
    for tenants in [2usize, 6, 10] {
        let mut cells = vec![tenants.to_string()];
        let mut jcts = [0.0f64; 2];
        for (i, all_in_cos) in [false, true].into_iter().enumerate() {
            let mut cfg = common::bench_config_or_sim();
            cfg.bandwidth = None; // overload the COS, not the network
            cfg.train_batch = 100;
            let bed = Testbed::launch(cfg).unwrap();
            // Pre-materialise one dataset per distinct model + warm.
            let mut seen = std::collections::BTreeSet::new();
            for tnt in 0..tenants {
                let model = tenant_model_for(&bed.cfg, tnt);
                if seen.insert(model) {
                    bed.dataset(&format!("f12-{model}"), model, 100).unwrap();
                    bed.server.warm(model).unwrap();
                }
            }
            let report = run_tenants_with(
                tenants,
                |tnt| tenant_model_for(&bed.cfg, tnt),
                |_tnt, model| {
                    let (ds, labels) = {
                        let app = bed.app(model)?;
                        let spec = hapi::client::DatasetSpec {
                            name: format!("f12-{model}"),
                            input_shape: app.meta().input_shape.clone(),
                            num_classes: app.meta().num_classes,
                            num_samples: 100,
                            shard_samples: bed.cfg.object_samples,
                            seed: bed.cfg.seed,
                        };
                        let labels: Vec<i32> =
                            spec.shards().flat_map(|(_, l)| l).collect();
                        (spec.to_ref(), labels)
                    };
                    if all_in_cos {
                        bed.all_in_cos_client(model)?.train_epoch(&ds)?;
                    } else {
                        bed.hapi_client(model, DeviceKind::Gpu)?
                            .train_epoch(&ds, &labels)?;
                    }
                    Ok(())
                },
            );
            assert_eq!(
                report.failures(),
                0,
                "tenants={tenants} all_in_cos={all_in_cos}: failures \
                 {:?}",
                report
                    .results
                    .iter()
                    .filter(|r| !r.ok)
                    .map(|r| (&r.model, &r.error))
                    .collect::<Vec<_>>()
            );
            cells.push(fmt_duration(report.makespan));
            cells.push(fmt_duration(report.avg_jct()));
            jcts[i] = report.avg_jct().as_secs_f64();
            bed.stop();
        }
        let ratio = jcts[1] / jcts[0];
        ratios.push(ratio);
        cells.push(format!("{ratio:.2}x"));
        // reorder cells: tenants, hapi mk, hapi jct, aic mk, aic jct, ratio
        t.row(cells);
    }
    t.print();
    println!(
        "\npaper shape: ALL_IN_COS/Hapi JCT ratio grows with tenants \
         (up to 4.9x at 10 tenants in the paper); measured: {ratios:?}\n\
         NB: on this single-box testbed every tenant's client shares the \
         COS CPU, so Hapi's moved-to-client work is not free parallelism \
         as in the paper — the ratio trend survives, its magnitude is \
         muted (EXPERIMENTS.md)."
    );
    if hlo {
        assert!(
            ratios.last().unwrap() + 0.05 >= *ratios.first().unwrap(),
            "ALL_IN_COS should degrade (or at least not improve) with \
             tenants"
        );
        assert!(
            *ratios.last().unwrap() >= 0.95,
            "at 10 tenants ALL_IN_COS must not meaningfully beat Hapi"
        );
    } else {
        // Instantaneous sim compute leaves both systems overhead-bound:
        // the JCT-ratio *shape* is only meaningful on the HLO backend,
        // so the sim smoke checks completion (0 failures above), not
        // the ratio.
        println!("(sim backend: JCT-ratio shape assertions skipped)");
    }

    lane_isolation();
    planner_scale_sweep(&[100, 1000]);
}

/// Per-client gather lanes: a burst-1 tenant trains next to a co-tenant
/// of growing pipeline depth; the shallow tenant's lane gather window
/// (its time-to-grant overhead) must not grow with the co-tenant's
/// `depth × shards` burst.
fn lane_isolation() {
    println!("\n== Fig 12b: lane isolation vs co-tenant depth ==\n");
    let mut t = Table::new(
        "burst-1 tenant's lane gather vs co-tenant depth",
        &["co-tenant depth", "co burst", "shallow lane p95 gather"],
    );
    let mut shallow_p95 = Vec::new();
    for co_depth in [1usize, 4, 8] {
        let mut cfg = common::bench_config_or_sim();
        cfg.bandwidth = None;
        // Shallow tenant: one shard per iteration, depth 1 → burst 1.
        cfg.train_batch = cfg.object_samples;
        let bed = Testbed::launch(cfg).unwrap();
        let model = tenant_model_for(&bed.cfg, 0);
        let samples = 10 * bed.cfg.object_samples;
        let (ds, labels) = bed.dataset("f12b", model, samples).unwrap();
        bed.server.warm(model).unwrap();

        let shallow = bed.hapi_client(model, DeviceKind::Gpu).unwrap();
        let mut deep_cfg = bed.cfg.clone();
        deep_cfg.pipeline_depth = co_depth;
        let co_burst = co_depth; // × 1 shard/iter at this train_batch
        let mut deep = hapi::client::HapiClient::from_backend(
            bed.app(model).unwrap(),
            bed.backend(model).unwrap(),
            deep_cfg,
            bed.addrs(),
            bed.net.clone(),
            DeviceKind::Gpu,
            None,
        );
        deep.set_registry(bed.registry.clone());
        let shallow_lane = shallow.client_id();

        std::thread::scope(|scope| {
            let h1 = scope.spawn(|| shallow.train_epoch(&ds, &labels));
            let h2 = scope.spawn(|| deep.train_epoch(&ds, &labels));
            h1.join().unwrap().unwrap();
            h2.join().unwrap().unwrap();
        });

        let h = bed.registry.histogram(&names::lane_gather_window_ns(shallow_lane));
        assert!(h.count() > 0, "shallow tenant never gathered");
        let p95 = h.p95();
        shallow_p95.push(p95);
        t.row(vec![
            co_depth.to_string(),
            co_burst.to_string(),
            format!("{:.3} ms", p95 as f64 / 1e6),
        ]);
        bed.stop();
    }
    t.print();
    // Independence: the shallow tenant's gather overhead must not scale
    // with the co-tenant's burst.  3 ms (the planner's idle-exit bound)
    // is far below the 12 ms window a shared gather would impose at
    // depth 8 — and well above scheduler noise.
    for (i, &p95) in shallow_p95.iter().enumerate() {
        assert!(
            p95 < 3_000_000,
            "shallow lane gathered {p95} ns with co-tenant depth \
             {} — its window scaled with a co-tenant's burst",
            [1, 4, 8][i]
        );
    }
    println!(
        "burst-1 tenant's lane gather stays flat as the co-tenant's \
         burst grows: {shallow_p95:?} ns — grants are independent of \
         co-tenant depth × shards."
    );
}

/// Thousand-tenant planner sweep: N concurrent tenants (one gather
/// lane each) hammer a bare planner; reports p99 time-to-grant and
/// grant throughput.  Device memory scales with N (N/10 full-batch
/// grants fit at once) so contention and queueing — not Eq. 4
/// infeasibility — are what is measured.  This is the O(1000)-lane
/// scalability pin for the sharded lane table, per-ticket gates, and
/// indexed solve.
fn planner_scale_sweep(scales: &[usize]) {
    use hapi::metrics::Registry;
    use hapi::runtime::DeviceSim;
    use hapi::server::Planner;

    println!("\n== Fig 12c: planner scale (time-to-grant) ==\n");
    let mut t = Table::new(
        "planner scale: N concurrent tenants × 5 grants each",
        &["tenants", "grants", "p99 time-to-grant", "grants/sec"],
    );
    const GRANTS_EACH: usize = 5;
    for &n in scales {
        let reg = Registry::new();
        let devices = vec![DeviceSim::new(
            "scale-gpu0",
            DeviceKind::Gpu,
            2_000 * (n as u64 / 10).max(10),
            0,
        )];
        let planner = std::sync::Arc::new(Planner::new(
            devices,
            20,
            true,
            reg.clone(),
        ));
        let t0 = std::time::Instant::now();
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n);
            for i in 0..n {
                let p = planner.clone();
                let h = std::thread::Builder::new()
                    .stack_size(128 * 1024)
                    .spawn_scoped(scope, move || {
                        for _ in 0..GRANTS_EACH {
                            let grant = p
                                .admit(0, 100, 0, 20, 20, 1, i as u64 + 1)
                                .expect("grant");
                            drop(grant);
                        }
                    })
                    .expect("spawn tenant");
                handles.push(h);
            }
            for h in handles {
                h.join().expect("tenant thread");
            }
        });
        let elapsed = t0.elapsed();
        let grants = reg.counter(names::BA_GRANTS).get();
        assert_eq!(
            grants,
            (n * GRANTS_EACH) as u64,
            "every admission must end in a grant"
        );
        let p99 = reg.histogram(names::BA_TIME_TO_GRANT_NS).p99();
        t.row(vec![
            n.to_string(),
            grants.to_string(),
            format!("{:.3} ms", p99 as f64 / 1e6),
            format!(
                "{:.0}",
                hapi::benchkit::throughput(grants, elapsed)
            ),
        ]);
        planner.shutdown();
    }
    t.print();
    println!(
        "per-pass planner work is indexed by touched lanes, so \
         time-to-grant stays bounded as tenants grow 100 → 1000."
    );
}
