//! Fig 12 — scalability with multiple tenants vs ALL_IN_COS.
//!
//! N tenants (2, 6, 10) submit one job each at t=0, models round-robin
//! over Table 1 (§7.5), training batch 100 (paper: 1000).  Reports
//! makespan and average JCT for Hapi and ALL_IN_COS.
//!
//! Expected shape: comparable at few tenants; ALL_IN_COS falls behind as
//! tenants grow (no batch decoupling: each job occupies the COS at the
//! training batch size and jobs serialise).

#[path = "common.rs"]
mod common;

use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::runtime::DeviceKind;
use hapi::util::fmt_duration;
use hapi::workload::{run_tenants, tenant_model};

fn main() {
    println!("== Fig 12: multi-tenant scalability ==\n");
    let mut t = Table::new(
        "Hapi vs ALL_IN_COS",
        &[
            "tenants",
            "Hapi makespan",
            "Hapi avg JCT",
            "AIC makespan",
            "AIC avg JCT",
            "JCT ratio",
        ],
    );
    let mut ratios = Vec::new();
    for tenants in [2usize, 6, 10] {
        let mut cells = vec![tenants.to_string()];
        let mut jcts = [0.0f64; 2];
        for (i, all_in_cos) in [false, true].into_iter().enumerate() {
            let mut cfg = common::bench_config();
            cfg.bandwidth = None; // overload the COS, not the network
            cfg.train_batch = 100;
            let bed = Testbed::launch(cfg).unwrap();
            // Pre-materialise one dataset per distinct model + warm.
            let mut seen = std::collections::BTreeSet::new();
            for tnt in 0..tenants {
                let model = tenant_model(tnt);
                if seen.insert(model) {
                    bed.dataset(&format!("f12-{model}"), model, 100).unwrap();
                    bed.server.warm(model).unwrap();
                }
            }
            let report = run_tenants(tenants, |_t, model| {
                let (ds, labels) = {
                    let app = bed.app(model)?;
                    let spec = hapi::client::DatasetSpec {
                        name: format!("f12-{model}"),
                        input_shape: app.meta().input_shape.clone(),
                        num_classes: app.meta().num_classes,
                        num_samples: 100,
                        shard_samples: bed.cfg.object_samples,
                        seed: bed.cfg.seed,
                    };
                    let labels: Vec<i32> =
                        spec.shards().flat_map(|(_, l)| l).collect();
                    (spec.to_ref(), labels)
                };
                if all_in_cos {
                    bed.all_in_cos_client(model)?.train_epoch(&ds)?;
                } else {
                    bed.hapi_client(model, DeviceKind::Gpu)?
                        .train_epoch(&ds, &labels)?;
                }
                Ok(())
            });
            assert_eq!(
                report.failures(),
                0,
                "tenants={tenants} all_in_cos={all_in_cos}: failures \
                 {:?}",
                report
                    .results
                    .iter()
                    .filter(|r| !r.ok)
                    .map(|r| (&r.model, &r.error))
                    .collect::<Vec<_>>()
            );
            cells.push(fmt_duration(report.makespan));
            cells.push(fmt_duration(report.avg_jct()));
            jcts[i] = report.avg_jct().as_secs_f64();
            bed.stop();
        }
        let ratio = jcts[1] / jcts[0];
        ratios.push(ratio);
        cells.push(format!("{ratio:.2}x"));
        // reorder cells: tenants, hapi mk, hapi jct, aic mk, aic jct, ratio
        t.row(cells);
    }
    t.print();
    println!(
        "\npaper shape: ALL_IN_COS/Hapi JCT ratio grows with tenants \
         (up to 4.9x at 10 tenants in the paper); measured: {ratios:?}\n\
         NB: on this single-box testbed every tenant's client shares the \
         COS CPU, so Hapi's moved-to-client work is not free parallelism \
         as in the paper — the ratio trend survives, its magnitude is \
         muted (EXPERIMENTS.md)."
    );
    assert!(
        ratios.last().unwrap() + 0.05 >= *ratios.first().unwrap(),
        "ALL_IN_COS should degrade (or at least not improve) with tenants"
    );
    assert!(
        *ratios.last().unwrap() >= 0.95,
        "at 10 tenants ALL_IN_COS must not meaningfully beat Hapi"
    );
}
