//! Fig 16 (extension) — pipeline-depth sweep: per-iteration stall and
//! epoch time vs `pipeline_depth`, Hapi on the SimBackend under a shaped
//! link with modeled COS compute.
//!
//! This is the fig10-style axis for the prefetch engine: with per-POST
//! COS latency (feature extraction) comparable to client compute, depth
//! 1 (classic double buffering) leaves the trainer stalled for the part
//! of the fetch that compute does not cover; deeper windows start later
//! iterations' POSTs earlier and hide that latency.  Expected shape:
//! depth ≥ 2 strictly reduces per-iteration stall vs depth 1, with
//! diminishing returns once the window covers the fetch/compute ratio.
//!
//! Artifact-free by construction (SimBackend): runs on a fresh clone.

use hapi::config::HapiConfig;
use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::runtime::DeviceKind;

struct Row {
    depth: usize,
    epoch_secs: f64,
    stall_ms_per_iter: f64,
    inflight_max: usize,
}

fn run_depth(depth: usize) -> Row {
    let mut cfg = HapiConfig::sim();
    cfg.pipeline_depth = depth;
    // Balance the stages so overlap matters: ~86 ms of modeled COS
    // feature extraction per POST, ~65 ms of client compute per
    // iteration, ~19 ms of link transfer (2 MB/s shaped).
    cfg.sim_compute_gflops = 1.0;
    cfg.bandwidth = Some(2_000_000); // bytes/sec: a 16 Mbps link
    cfg.train_batch = 100;
    let bed = Testbed::launch(cfg).expect("launch");
    let (ds, labels) = bed
        .dataset("f16", "simnet", 1200)
        .expect("dataset");
    let client = bed
        .hapi_client("simnet", DeviceKind::Gpu)
        .expect("client");
    let t0 = std::time::Instant::now();
    let stats = client.train_epoch(&ds, &labels).expect("epoch");
    let epoch_secs = t0.elapsed().as_secs_f64();
    bed.stop();
    Row {
        depth,
        epoch_secs,
        stall_ms_per_iter: stats.comm.as_secs_f64() * 1e3
            / stats.iterations as f64,
        inflight_max: stats.max_inflight,
    }
}

fn main() {
    println!("== Fig 16: pipeline depth sweep (sim backend) ==\n");
    let rows: Vec<Row> = [1usize, 2, 4, 8].iter().map(|&d| run_depth(d)).collect();

    let mut t = Table::new(
        "Hapi, simnet, shaped 2 MB/s link, modeled COS compute",
        &["depth", "epoch (s)", "stall/iter (ms)", "max in-flight"],
    );
    for r in &rows {
        t.row(vec![
            r.depth.to_string(),
            format!("{:.2}", r.epoch_secs),
            format!("{:.1}", r.stall_ms_per_iter),
            r.inflight_max.to_string(),
        ]);
    }
    t.print();

    let d1 = &rows[0];
    let d2 = &rows[1];
    println!(
        "\ndepth 2 vs 1: stall {:.1} -> {:.1} ms/iter ({:.0}% less), \
         epoch {:.2} -> {:.2} s",
        d1.stall_ms_per_iter,
        d2.stall_ms_per_iter,
        100.0 * (1.0 - d2.stall_ms_per_iter / d1.stall_ms_per_iter.max(1e-9)),
        d1.epoch_secs,
        d2.epoch_secs,
    );
    for r in &rows {
        assert!(
            r.inflight_max <= r.depth,
            "backpressure violated at depth {}",
            r.depth
        );
    }
    assert!(
        d2.stall_ms_per_iter < d1.stall_ms_per_iter,
        "depth 2 must strictly reduce per-iteration stall \
         ({:.2} ms vs {:.2} ms)",
        d2.stall_ms_per_iter,
        d1.stall_ms_per_iter
    );
    println!("PASS: depth >= 2 strictly reduces per-iteration stall");
}
