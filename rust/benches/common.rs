//! Shared scaffolding for the figure/table benches.
//!
//! Every bench regenerates one table or figure of the paper's evaluation
//! (§3, §7) at the 1:10 tiny scale (DESIGN.md §2) and prints the same
//! rows/series the paper reports.  Absolute numbers differ (simulated
//! substrate); the *shape* — who wins, by what factor, where crossovers
//! fall — is the reproduction target recorded in EXPERIMENTS.md.

use hapi::config::HapiConfig;

/// Default bench config: discovered artifacts + paper-mapped knobs.
#[allow(dead_code)] // each bench uses the variant it needs
pub fn bench_config() -> HapiConfig {
    let mut cfg = HapiConfig::default();
    cfg.artifacts_dir = HapiConfig::discover_artifacts()
        .expect("run `make artifacts` before cargo bench");
    cfg
}

/// Bench config that degrades to the artifact-free sim backend on a
/// fresh clone — for benches that double as CI smokes (fig12).
#[allow(dead_code)] // each bench uses the variant it needs
pub fn bench_config_or_sim() -> HapiConfig {
    HapiConfig::discovered_or_sim()
}

/// The four models of the §3 measurement study.
#[allow(dead_code)] // each bench uses the subset it needs
pub const STUDY_MODELS: [&str; 4] =
    ["alexnet", "resnet18", "vgg11", "densenet121"];

/// Scale helper: the paper's batch knob divided by 10 (DESIGN.md §2).
#[allow(dead_code)]
pub fn scaled(paper_value: usize) -> usize {
    (paper_value / 10).max(1)
}

#[allow(dead_code)]
fn main() {}
