//! L3 hot-path micro-benchmarks (the §Perf baseline in EXPERIMENTS.md).
//!
//! Targets, per the paper's own budgets:
//! - the batch-adaptation solve must stay well under the paper's 25 ms
//!   per run;
//! - the proxy frame path and feature-tensor (de)serialisation must not
//!   bottleneck a multi-MB/s request stream;
//! - micro-batch chunk/pad/concat is on the per-request path;
//! - the transport scheduler's goodput-estimator update runs on
//!   **every shard completion** — it must stay lock-free/amortised
//!   (sub-microsecond scale, a rounding error next to any fetch).
//!
//! `--json [PATH]` additionally writes every bench's stats as a
//! machine-readable report (default `BENCH_9.json`), e.g.
//! `cargo bench --bench micro_hotpaths -- --json`.

#[path = "common.rs"]
mod common;

use hapi::batch::{solve, BatchRequest};
use hapi::benchkit::{json_path, Bench, BenchReport};
use hapi::cli::Args;
use hapi::cos::protocol::{Request, Response};
use hapi::runtime::Tensor;
use hapi::server::request::PostRequest;
use hapi::util::json::Json;
use hapi::util::rng::Rng;

fn main() {
    let args = Args::from_env().expect("args");
    let mut report = BenchReport::new("micro_hotpaths");
    println!("== L3 hot-path microbenches ==\n");

    // 1. Eq. 4 solve: 10 queued requests (the paper's max tenancy).
    let reqs: Vec<BatchRequest> = (0..10)
        .map(|i| BatchRequest {
            id: i,
            data_bytes_per_sample: 50_000 + i * 1000,
            model_bytes: 500_000,
            b_max: 100,
        })
        .collect();
    let stats = Bench::new("ba_solve_10_requests")
        .samples(50, 2000)
        .budget(std::time::Duration::from_secs(2))
        .run(|| solve(&reqs, 16 << 20, 20, 20).unwrap());
    assert!(
        stats.p50 < std::time::Duration::from_millis(25),
        "BA solve exceeds the paper's 25 ms budget"
    );
    report.stats("ba_solve_10_requests", &stats);

    // 2. POST header build + parse (JSON on the request path).
    let post = PostRequest {
        id: 42,
        model: "alexnet".into(),
        split_idx: 13,
        object: "ds/shard_00042".into(),
        labels_object: String::new(),
        input_dims: vec![100, 3, 32, 32],
        b_max: 100,
        mem_data_per_sample: 47_520,
        mem_model_bytes: 1_234_567,
        burst_width: 8,
        client_id: 3,
        mode: hapi::server::request::RequestMode::FeatureExtract,
    };
    let stats = Bench::new("post_header_roundtrip")
        .samples(50, 5000)
        .budget(std::time::Duration::from_secs(2))
        .run(|| {
            let j = post.to_json();
            PostRequest::parse(&j).unwrap()
        });
    report.stats("post_header_roundtrip", &stats);

    // 3. Wire frame encode/decode of a 1 MiB feature tensor response.
    let body = vec![7u8; 1 << 20];
    let header = Json::parse(r#"{"req_id": 1, "out_dims": [100, 8, 16, 16]}"#)
        .unwrap();
    let stats = Bench::new("response_encode_1MiB")
        .samples(20, 500)
        .budget(std::time::Duration::from_secs(2))
        .run(|| {
            let r = Response::OkPost(header.clone(), body.clone());
            let (op, payload) = r.encode();
            Response::decode(op, payload).unwrap()
        });
    report.stats("response_encode_1MiB", &stats);

    // 4. GET request frame (tiny, latency-bound).
    let stats = Bench::new("get_request_encode")
        .samples(50, 10_000)
        .budget(std::time::Duration::from_secs(1))
        .run(|| {
            let (op, p) = Request::Get("ds/shard_00001".into()).encode();
            Request::decode(op, p).unwrap()
        });
    report.stats("get_request_encode", &stats);

    // 5. Micro-batch chunk/pad/concat of a 100×(3·32·32) batch.
    let mut rng = Rng::new(1);
    let vals: Vec<f32> = (0..100 * 3072).map(|_| rng.normal()).collect();
    let tensor = Tensor::from_f32(vec![100, 3, 32, 32], &vals);
    let stats = Bench::new("chunk_pad_concat_100x3072")
        .samples(50, 2000)
        .budget(std::time::Duration::from_secs(2))
        .run(|| {
            let parts: Vec<Tensor> = (0..5)
                .map(|i| tensor.slice_batch(i * 20, 20).pad_batch(20))
                .collect();
            Tensor::concat_batch(&parts).unwrap()
        });
    report.stats("chunk_pad_concat_100x3072", &stats);

    // 6. Transport-scheduler estimator update (per shard completion:
    // EWMA fold + winner accounting + amortised re-pin check).  The
    // 100 µs p50 budget is ~100× headroom over the expected cost and
    // ~1000× under the cheapest sim fetch it rides on.
    {
        use hapi::client::pipeline::Transport;
        use hapi::client::{ShardCtx, TransportScheduler};
        use hapi::metrics::Registry;
        use hapi::netsim::Topology;

        let mut cfg = hapi::config::HapiConfig::sim();
        cfg.net_paths = 2;
        cfg.repin_threshold_pct = 60;
        cfg.repin_interval_ms = 50;
        cfg.hedge_factor_pct = 100;
        let reg = Registry::new();
        let net = Topology::new(&cfg.topology_spec());
        let sched = TransportScheduler::new(&cfg, 1, &net, 8, &reg);
        let ctx = ShardCtx {
            conn: 3,
            attempt: 0,
            path: 1,
            hedge: false,
        };
        let stats = Bench::new("transport_estimator_update")
            .samples(50, 20_000)
            .budget(std::time::Duration::from_secs(2))
            .run(|| {
                sched.on_fetch(
                    ctx,
                    50_000,
                    std::time::Duration::from_millis(2),
                    true,
                );
            });
        assert!(
            stats.p50 < std::time::Duration::from_micros(100),
            "estimator update too slow for the shard hot path: {:?}",
            stats.p50
        );
        report.stats("transport_estimator_update", &stats);
    }

    // 7. Gradient accumulation over a 1 M-element tail.
    let grads: Vec<Tensor> =
        vec![Tensor::from_f32(vec![1 << 20], &vec![0.5; 1 << 20])];
    let stats = Bench::new("grad_accumulate_1M")
        .samples(20, 200)
        .budget(std::time::Duration::from_secs(2))
        .run(|| {
            let mut acc =
                vec![Tensor::from_f32(vec![1 << 20], &vec![0.1; 1 << 20])];
            hapi::runtime::ModelArtifacts::accumulate(&mut acc, &grads)
                .unwrap();
            acc
        });
    report.stats("grad_accumulate_1M", &stats);

    // 8. Planner pass cost at 1000 pending gather lanes.  A held
    // device lease leaves headroom for exactly one grant, so ~1000
    // lanes stay queued while the planner solves continuously; the
    // per-pass solve (`ba.solve_ns`) must stay far under the paper's
    // 25 ms budget even at 100× the paper's tenancy — the pin for the
    // sharded lane table and the indexed (touched-lanes-only) solve.
    {
        use hapi::metrics::{names, Registry};
        use hapi::runtime::DeviceSim;
        use hapi::server::Planner;
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        const LANES: usize = 1000;
        let reg = Registry::new();
        let capacity = 4_000u64;
        let devices = vec![DeviceSim::new(
            "micro-gpu0",
            hapi::runtime::DeviceKind::Gpu,
            capacity,
            0,
        )];
        let device = devices[0].clone();
        let planner = Arc::new(Planner::new(devices, 20, true, reg.clone()));
        // One 2 000-byte grant of headroom: every pass makes progress,
        // yet the lane table stays full while we sample.
        let hold = device.admit(capacity - 2_000).expect("hold lease");
        let stop = Arc::new(AtomicBool::new(false));
        let waiters: Vec<_> = (0..LANES)
            .map(|i| {
                let p = planner.clone();
                let stop = stop.clone();
                std::thread::Builder::new()
                    .stack_size(128 * 1024)
                    .spawn(move || {
                        while !stop.load(Ordering::Relaxed) {
                            match p.admit(0, 100, 0, 20, 20, 1, i as u64 + 1) {
                                Ok(grant) => drop(grant),
                                Err(_) => break, // planner shut down
                            }
                        }
                    })
                    .expect("spawn lane")
            })
            .collect();
        let solve = reg.histogram(names::BA_SOLVE_NS);
        let t0 = std::time::Instant::now();
        while solve.count() < 50
            && t0.elapsed() < std::time::Duration::from_secs(20)
        {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        stop.store(true, Ordering::Relaxed);
        planner.shutdown();
        drop(hold);
        for w in waiters {
            w.join().expect("lane thread");
        }
        assert!(solve.count() > 0, "planner never completed a pass");
        let p50 = solve.p50();
        println!(
            "bench {:40} p50 {:.3} ms over {} passes at {LANES} lanes",
            "planner_pass_1000_lanes",
            p50 as f64 / 1e6,
            solve.count()
        );
        assert!(
            p50 < 10_000_000,
            "planner pass p50 {p50} ns at {LANES} lanes blows the 10 ms pin"
        );
        report.value("planner_pass_1000_lanes_p50_ns", p50 as f64);
    }

    if let Some(path) = json_path(&args) {
        report.write(&path).expect("write bench report");
        println!("\nwrote {path}");
    }
}
