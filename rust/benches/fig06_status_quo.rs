//! Fig 6 — status quo: communication/computation breakdown of the
//! BASELINE (train in the compute tier, stream images from the COS) at a
//! rate-limited link.  The paper chokes a real GPU at 150 Mbps; our
//! "GPU" executes on a CPU core, so the equivalent choke point —
//! transfer time ≥ compute time — sits near 0.3 Mbps on this testbed
//! (EXPERIMENTS.md §Calibration maps the bandwidth axis).
//!
//! Expected shape: on the GPU tier the epoch is communication-bound (the
//! device idles waiting for data); on the CPU tier computation dominates.

#[path = "common.rs"]
mod common;

use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::netsim;
use hapi::runtime::DeviceKind;
use hapi::util::fmt_duration;

fn main() {
    let batch = common::scaled(500);
    println!(
        "== Fig 6: BASELINE comm/comp breakdown at 0.3 Mbps, batch {batch} ==\n"
    );
    let mut t = Table::new(
        "BASELINE breakdown",
        &["model", "client", "comm", "comp", "comm share", "status"],
    );
    for model in ["alexnet", "vgg11", "transformer"] {
        for device in [DeviceKind::Gpu, DeviceKind::Cpu] {
            let mut cfg = common::bench_config();
            cfg.bandwidth = Some(netsim::mbps(0.3));
            cfg.train_batch = batch;
            let bed = Testbed::launch(cfg).unwrap();
            let (ds, labels) = bed.dataset("f6", model, batch).unwrap();
            let client = bed.baseline_client(model, device).unwrap();
            let row = match client.train_epoch(&ds, &labels) {
                Ok(stats) => {
                    let comm = stats.comm.as_secs_f64();
                    let comp = stats.comp.as_secs_f64();
                    vec![
                        model.to_string(),
                        format!("{device:?}"),
                        fmt_duration(stats.comm),
                        fmt_duration(stats.comp),
                        format!("{:.0}%", 100.0 * comm / (comm + comp)),
                        "ok".into(),
                    ]
                }
                Err(e) if e.is_oom() => vec![
                    model.to_string(),
                    format!("{device:?}"),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "X (OOM)".into(),
                ],
                Err(e) => panic!("{model}: {e}"),
            };
            t.row(row);
            bed.stop();
        }
    }
    t.print();
    println!(
        "paper shape: GPU rows communication-bound, CPU rows \
         computation-bound; large models marked X"
    );
}
