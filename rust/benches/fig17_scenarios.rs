//! Fig 17 (extension) — chaos-scenario sweep: end-to-end cost of
//! injected faults vs a chaos-free reference run, driven by the
//! seed-replayable scenario engine (`hapi::scenario`).
//!
//! Each row replays one scenario script twice through the full sim
//! stack — once without its fault timeline (the reference) and once
//! with it — and reports the makespan inflation the chaos cost,
//! alongside the transport scheduler's visible reactions (probes,
//! migrations, hedges).  The headline is the safety envelope, not the
//! slowdown: every row must hold the fuzzer's three invariants
//! (bitwise loss identity, no lost work, metrics conservation), so a
//! degraded or crashed path may slow a run but can never change what
//! it computes.
//!
//! Rows sweep chaos intensity: the two canned regression scenarios
//! (degrade→recover with migrate-back, proxy crash→restart) plus a
//! slice of the fixed fuzz corpus at increasing event counts.  Any
//! violation aborts with the seed's one-command replay line
//! (`cargo run --release -- scenario --scenario-seed <seed>`).
//!
//! Artifact-free by construction (SimBackend): runs on a fresh clone.

use hapi::metrics::Table;
use hapi::scenario::{self, ScenarioOutcome, ScenarioScript};

struct Row {
    label: String,
    seed: u64,
    paths: usize,
    tenants: usize,
    events: usize,
    ref_secs: f64,
    chaos_secs: f64,
    probes: u64,
    repins: u64,
    hedges: u64,
}

/// Sum a client-side counter over every tenant's private registry.
fn tenant_sum(outcome: &ScenarioOutcome, name: &str) -> u64 {
    outcome
        .tenants
        .iter()
        .map(|t| t.registry.counter(name).get())
        .sum()
}

fn run_script(label: &str, script: &ScenarioScript) -> Row {
    let reference = scenario::run(script, false).expect("reference run");
    let chaos = scenario::run(script, true).expect("chaos run");
    let violations = scenario::verify(script, &reference, &chaos);
    assert!(
        violations.is_empty(),
        "{label}: invariant violations:\n  {}\nreplay: cargo run \
         --release -- scenario --scenario-seed {}",
        violations.join("\n  "),
        script.seed
    );
    Row {
        label: label.to_string(),
        seed: script.seed,
        paths: script.paths,
        tenants: script.tenants.len(),
        events: script.events.len(),
        ref_secs: reference.makespan.as_secs_f64(),
        chaos_secs: chaos.makespan.as_secs_f64(),
        probes: tenant_sum(&chaos, "pipeline.probes"),
        repins: tenant_sum(&chaos, "pipeline.repins"),
        hedges: tenant_sum(&chaos, "pipeline.hedges"),
    }
}

fn main() {
    println!("== Fig 17: chaos-scenario sweep (sim backend) ==\n");

    let mut rows = vec![
        run_script(
            "degrade->recover",
            &ScenarioScript::degrade_recover_migrate_back(),
        ),
        run_script(
            "crash->restart",
            &ScenarioScript::proxy_crash_restart(),
        ),
    ];
    // A slice of the fuzz corpus, ordered by scripted event count so
    // the table reads as a chaos-intensity sweep.
    let mut corpus: Vec<ScenarioScript> = [42u64, 1337, 0x5EED_CAFE]
        .iter()
        .map(|&s| ScenarioScript::random(s))
        .collect();
    corpus.sort_by_key(|s| s.events.len());
    for script in &corpus {
        rows.push(run_script(
            &format!("corpus seed {}", script.seed),
            script,
        ));
    }

    let mut t = Table::new(
        "scenario engine, reference vs chaos run of the same script",
        &[
            "scenario",
            "paths",
            "tenants",
            "events",
            "ref (s)",
            "chaos (s)",
            "slowdown",
            "probes",
            "repins",
            "hedges",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.label.clone(),
            r.paths.to_string(),
            r.tenants.to_string(),
            r.events.to_string(),
            format!("{:.2}", r.ref_secs),
            format!("{:.2}", r.chaos_secs),
            format!("{:.2}x", r.chaos_secs / r.ref_secs.max(1e-9)),
            r.probes.to_string(),
            r.repins.to_string(),
            r.hedges.to_string(),
        ]);
    }
    t.print();

    // The canned degrade scenario must show the full recovery arc.
    let deg = &rows[0];
    assert!(
        deg.probes >= 1 && deg.repins >= 1,
        "degrade scenario showed no probe/migration activity \
         (probes {}, repins {}) — seed {}",
        deg.probes,
        deg.repins,
        deg.seed
    );
    println!(
        "\nPASS: {} scenarios held bitwise loss identity, lost no \
         work, and conserved their metrics under chaos",
        rows.len()
    );
}
