//! Table 3 — Hapi server embedded in the proxy (Swift green-thread
//! style) vs decoupled with a dedicated compute pool.
//!
//! Expected shape: decoupled ≤ in-proxy (the paper's 331 vs 348 s etc.
//! — modest but consistent wins).  The mechanism reproduced here: green
//! threads serialise synchronous storage I/O behind CPU-bound ML work;
//! the decoupled pool overlaps them.

#[path = "common.rs"]
mod common;

use hapi::cos::proxy::ProxyMode;
use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::runtime::DeviceKind;
use hapi::util::fmt_duration;

fn main() {
    println!("== Table 3: in-proxy vs decoupled server ==\n");
    let models = ["resnet18", "resnet50", "alexnet", "densenet121"];
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for model in models {
        let mut times = [0.0f64; 2];
        for (i, mode) in
            [ProxyMode::InProxy, ProxyMode::Decoupled].iter().enumerate()
        {
            let mut cfg = common::bench_config();
            cfg.bandwidth = None;
            cfg.train_batch = 100;
            // Slow storage media (4 MB/s): the green-thread proxy
            // serialises these reads behind ML compute, the decoupled
            // design overlaps them — the Table 3 mechanism.
            cfg.storage_read_rate = Some(2_000_000);
            let bed = Testbed::launch_with_mode(cfg, *mode).unwrap();
            let (ds, labels) = bed.dataset("t3", model, 400).unwrap();
            bed.server.warm(model).unwrap();
            // One client, pipelined POSTs: the decoupled server overlaps
            // the next request's storage read with the current one's ML
            // compute; the green-thread proxy serialises them.
            let t0 = std::time::Instant::now();
            let c = bed.hapi_client(model, DeviceKind::Gpu).unwrap();
            c.train_epoch(&ds, &labels).unwrap();
            times[i] = t0.elapsed().as_secs_f64();
            bed.stop();
        }
        rows.push((model.to_string(), times[0], times[1]));
    }
    let mut t = Table::new(
        "request execution time",
        &["model", "in proxy", "decoupled", "decoupled wins?"],
    );
    for (model, in_proxy, decoupled) in &rows {
        t.row(vec![
            model.clone(),
            fmt_duration(std::time::Duration::from_secs_f64(*in_proxy)),
            fmt_duration(std::time::Duration::from_secs_f64(*decoupled)),
            if decoupled <= in_proxy { "yes" } else { "no" }.into(),
        ]);
    }
    t.print();
}
