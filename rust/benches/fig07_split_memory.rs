//! Fig 7 — device memory breakdown when splitting the forward pass at
//! different units: batch 10 before the split (the COS side), batch 100
//! after (paper: 100/1000 at 1:10 scale).
//!
//! Expected shape: a small pre-split batch combined with a later split
//! index shrinks total memory, sometimes below the no-split status quo.

#[path = "common.rs"]
mod common;

use hapi::config::Scale;
use hapi::metrics::Table;
use hapi::model::ModelRegistry;
use hapi::profiler::AppProfile;
use hapi::util::fmt_bytes;

fn main() {
    let cfg = common::bench_config();
    let reg = ModelRegistry::load_dir(cfg.profiles_dir()).unwrap();
    let pre_batch = common::scaled(100);
    let post_batch = common::scaled(1000);

    println!(
        "== Fig 7: memory with split fwd (b={pre_batch} before, \
         b={post_batch} after) ==\n"
    );
    for name in ["alexnet", "resnet18", "vgg11"] {
        let app = AppProfile::new(reg.get(name).unwrap(), Scale::Tiny);
        let mem = app.memory();
        let freeze = app.freeze_idx();
        // Status quo: everything at the post batch, no split.
        let status_quo = mem.fe_request_bytes(freeze, post_batch)
            + mem.backward_bytes(post_batch);
        let mut t = Table::new(
            &format!("{name} (status quo: {})", fmt_bytes(status_quo)),
            &["split idx", "before (COS)", "after (client)", "total", "< status quo?"],
        );
        // Candidate split indexes: units whose output < input (Fig 2).
        let candidates: Vec<usize> = (1..=freeze)
            .filter(|&i| app.out_bytes(i) < app.input_bytes())
            .collect();
        let mut totals = Vec::new();
        for &s in &candidates {
            let before = mem.fe_request_bytes(s, pre_batch);
            let after = mem.client_bytes(s, post_batch);
            let total = before + after;
            totals.push(total);
            t.row(vec![
                s.to_string(),
                fmt_bytes(before),
                fmt_bytes(after),
                fmt_bytes(total),
                if total < status_quo { "yes" } else { "" }.into(),
            ]);
        }
        t.print();
        assert!(
            totals.iter().any(|&t| t < status_quo),
            "{name}: no split beats the status quo"
        );
        // Later splits reduce the client side monotonically.
        let client_sides: Vec<u64> = candidates
            .iter()
            .map(|&s| mem.client_bytes(s, post_batch))
            .collect();
        assert!(
            client_sides.windows(2).all(|w| w[1] <= w[0]),
            "{name}: client memory should shrink with later splits"
        );
        println!();
    }
}
