//! Ablation — batch-adaptation solver design choices (DESIGN.md §6).
//!
//! Eq. 4 fixes the objective (pack the device) but not the *policy*.
//! This ablation compares the shipped smallest-first water-filling
//! against two plausible alternatives across randomized request mixes:
//!
//! - **equal-share**: split the budget evenly, ignore per-request costs;
//! - **largest-first**: greedily max out requests in arrival order (a
//!   FIFO-greedy a practitioner might write first).
//!
//! Metrics: memory utilisation (the Eq. 4 objective), admitted-request
//! count, and min/max batch fairness.  Water-filling should dominate
//! utilisation while keeping the fairest floor — the reason Hapi's
//! planner uses it.

#[path = "common.rs"]
mod common;

use hapi::batch::{solve, BatchRequest};
use hapi::metrics::Table;
use hapi::util::rng::Rng;

#[derive(Default, Clone, Copy)]
struct Agg {
    util: f64,
    admitted: f64,
    min_batch: f64,
    runs: f64,
}

fn cost(r: &BatchRequest, b: usize) -> u64 {
    r.model_bytes + b as u64 * r.data_bytes_per_sample
}

/// Policy A: the shipped solver.
fn water_filling(reqs: &[BatchRequest], budget: u64) -> Vec<(u64, usize)> {
    match solve(reqs, budget, 20, 20) {
        Ok(sol) => sol.assignments.iter().map(|a| (a.id, a.batch)).collect(),
        Err(_) => vec![],
    }
}

/// Policy B: equal share of the *budget*, clamped to bounds.
fn equal_share(reqs: &[BatchRequest], budget: u64) -> Vec<(u64, usize)> {
    let share = budget / reqs.len() as u64;
    reqs.iter()
        .filter_map(|r| {
            if r.model_bytes >= share {
                return None;
            }
            let b = ((share - r.model_bytes) / r.data_bytes_per_sample)
                as usize;
            let b = (b / 20 * 20).min(r.b_max);
            if b < 20.min(r.b_max) {
                None
            } else {
                Some((r.id, b))
            }
        })
        .collect()
}

/// Policy C: FIFO-greedy, each request takes its maximum that still fits.
fn largest_first(reqs: &[BatchRequest], budget: u64) -> Vec<(u64, usize)> {
    let mut used = 0u64;
    let mut out = Vec::new();
    for r in reqs {
        let mut b = r.b_max / 20 * 20;
        while b >= 20.min(r.b_max).max(1) {
            if used + cost(r, b) <= budget {
                used += cost(r, b);
                out.push((r.id, b));
                break;
            }
            if b < 20 {
                break;
            }
            b -= 20;
        }
    }
    out
}

fn main() {
    println!("== Ablation: Eq. 4 solver policies ==\n");
    let budget: u64 = 21 << 20;
    let policies: [(&str, fn(&[BatchRequest], u64) -> Vec<(u64, usize)>); 3] = [
        ("water-filling (Hapi)", water_filling),
        ("equal-share", equal_share),
        ("FIFO-greedy", largest_first),
    ];
    let mut aggs = [Agg::default(); 3];
    let trials = 500;
    for seed in 0..trials {
        let mut rng = Rng::new(seed);
        let n = rng.range(2, 10) as usize;
        let reqs: Vec<BatchRequest> = (0..n)
            .map(|i| BatchRequest {
                id: i as u64,
                data_bytes_per_sample: rng.range(20_000, 90_000),
                model_bytes: rng.range(100_000, 2_000_000),
                b_max: 100,
            })
            .collect();
        for (p, agg) in policies.iter().zip(aggs.iter_mut()) {
            let assign = (p.1)(&reqs, budget);
            let used: u64 = assign
                .iter()
                .map(|(id, b)| {
                    cost(reqs.iter().find(|r| r.id == *id).unwrap(), *b)
                })
                .sum();
            assert!(used <= budget, "{}: over budget", p.0);
            agg.util += used as f64 / budget as f64;
            agg.admitted += assign.len() as f64 / n as f64;
            agg.min_batch += assign
                .iter()
                .map(|(_, b)| *b)
                .min()
                .unwrap_or(0) as f64;
            agg.runs += 1.0;
        }
    }
    let mut t = Table::new(
        &format!("{trials} random request mixes, 21 MiB budget"),
        &["policy", "mean utilisation", "mean admitted", "mean min batch"],
    );
    for ((name, _), agg) in policies.iter().zip(&aggs) {
        t.row(vec![
            name.to_string(),
            format!("{:.1}%", 100.0 * agg.util / agg.runs),
            format!("{:.1}%", 100.0 * agg.admitted / agg.runs),
            format!("{:.1}", agg.min_batch / agg.runs),
        ]);
    }
    t.print();
    // The shipped policy must dominate utilisation.
    assert!(
        aggs[0].util >= aggs[1].util && aggs[0].util >= aggs[2].util,
        "water-filling should maximise the Eq. 4 objective"
    );
    println!("water-filling dominates utilisation: ok");
}
