//! Fig 11 + Table 4 — impact of the client↔COS bandwidth.
//!
//! Sweeps the link rate.  The paper sweeps 0.05–12 Gbps around its
//! testbed's comm/comp crossover; ours sits near 2 Mbps (CPU-tier
//! compute), so the sweep covers 0.5–24 Mbps — the same positions
//! relative to the crossover.  Runs
//! one epoch of Hapi and BASELINE each, reporting epoch time, bytes per
//! iteration, and the split index Algorithm 1 chose (Table 4).
//!
//! Expected shape: Hapi's curve is nearly flat (the split index walks
//! from the freeze layer toward early units as bandwidth grows) while
//! BASELINE degrades sharply at low bandwidth.

#[path = "common.rs"]
mod common;

use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::netsim;
use hapi::runtime::DeviceKind;
use hapi::util::fmt_bytes;

fn main() {
    let batch = common::scaled(2000);
    println!("== Fig 11 / Table 4: bandwidth sweep (alexnet, batch {batch}) ==\n");
    let mut t = Table::new(
        "bandwidth sweep",
        &[
            "bandwidth (Mbps)",
            "split idx",
            "Hapi time (s)",
            "Hapi bytes/iter",
            "BASE time (s)",
            "BASE bytes/iter",
        ],
    );
    let mut hapi_times = Vec::new();
    let mut base_times = Vec::new();
    let mut split_indices = Vec::new();
    for mbps in [0.5, 1.0, 2.0, 6.0, 24.0] {
        let mut cfg = common::bench_config();
        cfg.bandwidth = Some(netsim::mbps(mbps));
        cfg.train_batch = batch;
        let bed = Testbed::launch(cfg).unwrap();
        let (ds, labels) = bed.dataset("f11", "alexnet", batch).unwrap();
        bed.server.warm("alexnet").unwrap();

        let hapi = bed.hapi_client("alexnet", DeviceKind::Gpu).unwrap();
        let split = hapi.split.split_idx;
        let t0 = std::time::Instant::now();
        let hs = hapi.train_epoch(&ds, &labels).unwrap();
        let hapi_t = t0.elapsed().as_secs_f64();

        let base = bed.baseline_client("alexnet", DeviceKind::Gpu).unwrap();
        let t0 = std::time::Instant::now();
        let bs = base.train_epoch(&ds, &labels).unwrap();
        let base_t = t0.elapsed().as_secs_f64();

        t.row(vec![
            format!("{mbps}"),
            split.to_string(),
            format!("{hapi_t:.1}"),
            fmt_bytes(hs.bytes_from_cos / hs.iterations.max(1) as u64),
            format!("{base_t:.1}"),
            fmt_bytes(bs.bytes_from_cos / bs.iterations.max(1) as u64),
        ]);
        hapi_times.push(hapi_t);
        base_times.push(base_t);
        split_indices.push(split);
        bed.stop();
    }
    t.print();

    // Table 4 dynamic: split index non-increasing as bandwidth grows.
    assert!(
        split_indices.windows(2).all(|w| w[1] <= w[0]),
        "split indices should move earlier with more bandwidth: {split_indices:?}"
    );
    // Fig 11a shape: Hapi flat-ish, BASELINE steep.
    let hapi_ratio = hapi_times[0] / hapi_times.last().unwrap();
    let base_ratio = base_times[0] / base_times.last().unwrap();
    println!(
        "\nslowest/fastest epoch ratio — Hapi {hapi_ratio:.1}x vs \
         BASELINE {base_ratio:.1}x (paper: Hapi nearly flat)"
    );
    assert!(
        base_ratio > hapi_ratio,
        "BASELINE should degrade more with scarce bandwidth"
    );
}
