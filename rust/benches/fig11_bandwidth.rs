//! Fig 11 + Table 4 — impact of the client↔COS bandwidth.
//!
//! Sweeps the link rate.  The paper sweeps 0.05–12 Gbps around its
//! testbed's comm/comp crossover; ours sits near 2 Mbps (CPU-tier
//! compute), so the sweep covers 0.5–24 Mbps — the same positions
//! relative to the crossover.  Runs
//! one epoch of Hapi and BASELINE each, reporting epoch time, bytes per
//! iteration, and the split index Algorithm 1 chose (Table 4).
//!
//! Expected shape: Hapi's curve is nearly flat (the split index walks
//! from the freeze layer toward early units as bandwidth grows) while
//! BASELINE degrades sharply at low bandwidth.
//!
//! §fig11b (sim backend, artifact-free) degrades a *single path* of a
//! two-path topology mid-run: the tenant pinned to the starved path
//! re-decides its split toward the freeze layer through the per-window
//! re-measurement — the Table 4 dynamic, per path.  This is the
//! *algorithmic* answer to a degraded front end (push more work down);
//! the *transport* answer — re-pin connection slots to healthy paths
//! instead, keeping the split — is fig16's §fig16d
//! (`repin_threshold_pct`, off here so the split dynamic stays
//! isolated).

#[path = "common.rs"]
mod common;

use hapi::config::HapiConfig;
use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::netsim;
use hapi::runtime::DeviceKind;
use hapi::util::fmt_bytes;

/// §fig11b: adaptive split vs a single degraded path (sim backend).
fn per_path_degradation_section() {
    println!("== Fig 11b: adaptive split vs one degraded path (sim) ==\n");
    let mut cfg = HapiConfig::sim();
    cfg.net_paths = 2;
    cfg.bandwidth = Some(netsim::mbps(100.0));
    cfg.adaptive_split = true;
    cfg.pipeline_depth = 2;
    cfg.split_window_secs = 0.1;
    // One connection slot pins the tenant to one path: slot 0 of an
    // even client id lands on path 0 — the path we will degrade.
    cfg.fetch_fanout = 1;
    cfg.client_id = 2;
    let bed = Testbed::launch(cfg).unwrap();
    let (ds, labels) = bed.dataset("f11b", "simnet", 240).unwrap();
    let client = bed.hapi_client("simnet", DeviceKind::Gpu).unwrap();
    let initial = client.split.split_idx;
    let freeze = client.app.freeze_idx();
    // Path 0's front end collapses to 50 KB/s; path 1 stays at 100 Mbps.
    bed.net.set_path_rate(0, 50_000);
    let stats = client.train_epoch(&ds, &labels).unwrap();
    bed.stop();

    let mut t = Table::new(
        "Hapi simnet, 2 paths, path 0 degraded to 50 KB/s mid-run",
        &["iteration", "split idx"],
    );
    for (i, s) in stats.splits.iter().enumerate() {
        t.row(vec![i.to_string(), s.to_string()]);
    }
    t.print();

    assert!(
        *stats.splits.last().unwrap() > initial,
        "split never moved off the degraded path: {:?}",
        stats.splits
    );
    assert!(
        stats.splits.iter().all(|&s| s >= initial && s <= freeze),
        "split left [initial, freeze]: {:?}",
        stats.splits
    );
    println!(
        "\nPASS: one degraded path moved the split {} -> {} \
         (freeze {})\n",
        initial,
        stats.splits.last().unwrap(),
        freeze
    );
}

fn main() {
    per_path_degradation_section();

    if HapiConfig::discover_artifacts().is_none() {
        println!(
            "(artifacts not built: skipping the HLO bandwidth sweep — \
             run `make artifacts`)"
        );
        return;
    }
    let batch = common::scaled(2000);
    println!("== Fig 11 / Table 4: bandwidth sweep (alexnet, batch {batch}) ==\n");
    let mut t = Table::new(
        "bandwidth sweep",
        &[
            "bandwidth (Mbps)",
            "split idx",
            "Hapi time (s)",
            "Hapi bytes/iter",
            "BASE time (s)",
            "BASE bytes/iter",
        ],
    );
    let mut hapi_times = Vec::new();
    let mut base_times = Vec::new();
    let mut split_indices = Vec::new();
    for mbps in [0.5, 1.0, 2.0, 6.0, 24.0] {
        let mut cfg = common::bench_config();
        cfg.bandwidth = Some(netsim::mbps(mbps));
        cfg.train_batch = batch;
        let bed = Testbed::launch(cfg).unwrap();
        let (ds, labels) = bed.dataset("f11", "alexnet", batch).unwrap();
        bed.server.warm("alexnet").unwrap();

        let hapi = bed.hapi_client("alexnet", DeviceKind::Gpu).unwrap();
        let split = hapi.split.split_idx;
        let t0 = std::time::Instant::now();
        let hs = hapi.train_epoch(&ds, &labels).unwrap();
        let hapi_t = t0.elapsed().as_secs_f64();

        let base = bed.baseline_client("alexnet", DeviceKind::Gpu).unwrap();
        let t0 = std::time::Instant::now();
        let bs = base.train_epoch(&ds, &labels).unwrap();
        let base_t = t0.elapsed().as_secs_f64();

        t.row(vec![
            format!("{mbps}"),
            split.to_string(),
            format!("{hapi_t:.1}"),
            fmt_bytes(hs.bytes_from_cos / hs.iterations.max(1) as u64),
            format!("{base_t:.1}"),
            fmt_bytes(bs.bytes_from_cos / bs.iterations.max(1) as u64),
        ]);
        hapi_times.push(hapi_t);
        base_times.push(base_t);
        split_indices.push(split);
        bed.stop();
    }
    t.print();

    // Table 4 dynamic: split index non-increasing as bandwidth grows.
    assert!(
        split_indices.windows(2).all(|w| w[1] <= w[0]),
        "split indices should move earlier with more bandwidth: {split_indices:?}"
    );
    // Fig 11a shape: Hapi flat-ish, BASELINE steep.
    let hapi_ratio = hapi_times[0] / hapi_times.last().unwrap();
    let base_ratio = base_times[0] / base_times.last().unwrap();
    println!(
        "\nslowest/fastest epoch ratio — Hapi {hapi_ratio:.1}x vs \
         BASELINE {base_ratio:.1}x (paper: Hapi nearly flat)"
    );
    assert!(
        base_ratio > hapi_ratio,
        "BASELINE should degrade more with scarce bandwidth"
    );
}
