//! Fig 2 — per-layer output sizes vs application input sizes.
//!
//! Paper: bars = per-unit output size at batch 1; horizontal lines =
//! per-sample input size of ImageNet / iNatura / PlantLeaves.  The key
//! takeaway (early units already dip below the input size) must hold.

#[path = "common.rs"]
mod common;

use hapi::config::Scale;
use hapi::metrics::Table;
use hapi::model::{profiles::load_datasets, ModelRegistry};
use hapi::profiler::AppProfile;
use hapi::util::fmt_bytes;

fn main() {
    let cfg = common::bench_config();
    let reg = ModelRegistry::load_dir(cfg.profiles_dir()).unwrap();
    let datasets = load_datasets(
        cfg.profiles_dir().join("datasets.json"),
        Scale::Paper,
    )
    .unwrap();

    println!("== Fig 2: per-layer output sizes (paper-scale shapes) ==\n");
    let mut lines = String::from("dataset input sizes per sample: ");
    for d in &datasets {
        lines.push_str(&format!("{}={}  ", d.name, fmt_bytes(d.bytes_per_sample)));
    }
    println!("{lines}\n");

    for name in common::STUDY_MODELS {
        let app = AppProfile::new(reg.get(name).unwrap(), Scale::Paper);
        let mut t = Table::new(
            &format!("{name} (input {}/sample)", fmt_bytes(app.input_bytes())),
            &["unit", "name", "output/sample", "< input?"],
        );
        for i in 1..=app.num_units() {
            let out = app.out_bytes(i);
            t.row(vec![
                i.to_string(),
                app.meta().units[i - 1].name.clone(),
                fmt_bytes(out),
                if out < app.input_bytes() { "yes" } else { "" }.into(),
            ]);
        }
        t.print();
        let first_candidate = (1..=app.freeze_idx())
            .find(|&i| app.out_bytes(i) < app.input_bytes());
        println!(
            "earliest split candidate: unit {:?} (freeze {})\n",
            first_candidate,
            app.freeze_idx()
        );
        assert!(first_candidate.is_some(), "{name}: Fig 2 insight violated");
    }
}
