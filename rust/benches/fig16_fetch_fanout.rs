//! Fig 16 (extension) — fetch-fanout sweep: per-iteration network stall
//! vs `fetch_fanout`, Hapi on the SimBackend under a bandwidth-shaped
//! link with modeled COS compute.
//!
//! This is the sharded-fetch axis of the prefetch engine (the
//! depth-sweep sibling is `fig16_pipeline_depth`): with several shards
//! per iteration, fanout 1 drains every POST over a single COS
//! connection — each shard's server-side feature extraction and
//! round-trip serialise behind the previous one.  Fanout ≥ 2 fans the
//! shards over parallel connections so their COS compute and latency
//! overlap; only the wire bytes still serialise on the shaped link.
//! Expected shape: fanout ≥ 2 strictly reduces per-iteration stall vs
//! fanout 1, with diminishing returns once the pool covers the
//! shards-per-iteration.
//!
//! Artifact-free by construction (SimBackend): runs on a fresh clone.

use hapi::config::HapiConfig;
use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::runtime::DeviceKind;

struct Row {
    fanout: usize,
    epoch_secs: f64,
    stall_ms_per_iter: f64,
    inflight_max: usize,
}

fn run_fanout(fanout: usize) -> Row {
    let mut cfg = HapiConfig::sim();
    cfg.pipeline_depth = 1;
    cfg.fetch_fanout = fanout;
    // 5 shards per iteration (train batch 100 over 20-sample objects);
    // ~17 ms of modeled COS feature extraction per POST dominates the
    // per-shard cost, so serialising the 5 POSTs (fanout 1) leaves the
    // trainer stalled for most of the fetch.
    cfg.sim_compute_gflops = 5.0;
    cfg.bandwidth = Some(4_000_000); // bytes/sec: a 32 Mbps link
    cfg.train_batch = 100;
    let bed = Testbed::launch(cfg).expect("launch");
    let (ds, labels) = bed.dataset("f16f", "simnet", 1000).expect("dataset");
    let client = bed
        .hapi_client("simnet", DeviceKind::Gpu)
        .expect("client");
    let t0 = std::time::Instant::now();
    let stats = client.train_epoch(&ds, &labels).expect("epoch");
    let epoch_secs = t0.elapsed().as_secs_f64();
    bed.stop();
    Row {
        fanout,
        epoch_secs,
        stall_ms_per_iter: stats.comm.as_secs_f64() * 1e3
            / stats.iterations as f64,
        inflight_max: stats.max_inflight,
    }
}

fn main() {
    println!("== Fig 16b: fetch-fanout sweep (sim backend) ==\n");
    let rows: Vec<Row> =
        [1usize, 2, 4].iter().map(|&f| run_fanout(f)).collect();

    let mut t = Table::new(
        "Hapi, simnet, depth 1, 5 shards/iter, shaped 4 MB/s link",
        &["fanout", "epoch (s)", "stall/iter (ms)", "max in-flight"],
    );
    for r in &rows {
        t.row(vec![
            r.fanout.to_string(),
            format!("{:.2}", r.epoch_secs),
            format!("{:.1}", r.stall_ms_per_iter),
            r.inflight_max.to_string(),
        ]);
    }
    t.print();

    let f1 = &rows[0];
    let f2 = &rows[1];
    println!(
        "\nfanout 2 vs 1: stall {:.1} -> {:.1} ms/iter ({:.0}% less), \
         epoch {:.2} -> {:.2} s",
        f1.stall_ms_per_iter,
        f2.stall_ms_per_iter,
        100.0 * (1.0 - f2.stall_ms_per_iter / f1.stall_ms_per_iter.max(1e-9)),
        f1.epoch_secs,
        f2.epoch_secs,
    );
    for r in &rows {
        assert!(
            r.inflight_max <= 1,
            "backpressure violated at fanout {}",
            r.fanout
        );
    }
    assert!(
        f2.stall_ms_per_iter < f1.stall_ms_per_iter,
        "fanout 2 must strictly reduce per-iteration stall \
         ({:.2} ms vs {:.2} ms)",
        f2.stall_ms_per_iter,
        f1.stall_ms_per_iter
    );
    println!("PASS: fanout >= 2 strictly reduces per-iteration stall");
}
