//! Fig 16 (extension) — fetch-fanout sweep: per-iteration network stall
//! vs `fetch_fanout`, Hapi on the SimBackend under a bandwidth-shaped
//! link with modeled COS compute.
//!
//! This is the sharded-fetch axis of the prefetch engine (the
//! depth-sweep sibling is `fig16_pipeline_depth`): with several shards
//! per iteration, fanout 1 drains every POST over a single COS
//! connection — each shard's server-side feature extraction and
//! round-trip serialise behind the previous one.  Fanout ≥ 2 fans the
//! shards over parallel connections so their COS compute and latency
//! overlap; only the wire bytes still serialise on the shaped link.
//! Expected shape: fanout ≥ 2 strictly reduces per-iteration stall vs
//! fanout 1, with diminishing returns once the pool covers the
//! shards-per-iteration.
//!
//! §fig16c adds the multi-path axis: with per-path token buckets
//! (`net_paths`, one proxy front end per path) the same fanout stops
//! being a latency tool and becomes aggregate-bandwidth scaling —
//! throughput grows ~linearly in the path count at equal per-path rate,
//! until the client-NIC aggregate cap binds; the learning trajectory
//! stays bitwise identical throughout.
//!
//! §fig16d is the degraded-path recovery demo: one of two paths drops
//! to 25% of its rate mid-run.  Static pinning leaves half the slots
//! straggling on the slow front end for the rest of the epoch; the
//! goodput-aware transport scheduler (`repin_threshold_pct`) migrates
//! them to the healthy path (with hedged fetches bridging the
//! transition under a hard byte cap) and must recover ≥ 30% of the
//! throughput static pinning lost vs the never-degraded run — with a
//! bitwise-identical loss trajectory throughout.
//!
//! Artifact-free by construction (SimBackend): runs on a fresh clone.
//!
//! `--json [PATH]` additionally writes every section's headline
//! numbers as a machine-readable report (default `BENCH_9.json`).

use hapi::benchkit::{json_path, BenchReport};
use hapi::cli::Args;
use hapi::config::HapiConfig;
use hapi::harness::Testbed;
use hapi::metrics::{names, Table};
use hapi::runtime::DeviceKind;

struct Row {
    fanout: usize,
    epoch_secs: f64,
    stall_ms_per_iter: f64,
    inflight_max: usize,
}

fn run_fanout(fanout: usize) -> Row {
    let mut cfg = HapiConfig::sim();
    cfg.pipeline_depth = 1;
    cfg.fetch_fanout = fanout;
    // 5 shards per iteration (train batch 100 over 20-sample objects);
    // ~17 ms of modeled COS feature extraction per POST dominates the
    // per-shard cost, so serialising the 5 POSTs (fanout 1) leaves the
    // trainer stalled for most of the fetch.
    cfg.sim_compute_gflops = 5.0;
    cfg.bandwidth = Some(4_000_000); // bytes/sec: a 32 Mbps link
    cfg.train_batch = 100;
    let bed = Testbed::launch(cfg).expect("launch");
    let (ds, labels) = bed.dataset("f16f", "simnet", 1000).expect("dataset");
    let client = bed
        .hapi_client("simnet", DeviceKind::Gpu)
        .expect("client");
    let t0 = std::time::Instant::now();
    let stats = client.train_epoch(&ds, &labels).expect("epoch");
    let epoch_secs = t0.elapsed().as_secs_f64();
    bed.stop();
    Row {
        fanout,
        epoch_secs,
        stall_ms_per_iter: stats.comm.as_secs_f64() * 1e3
            / stats.iterations as f64,
        inflight_max: stats.max_inflight,
    }
}

/// One row of the §fig16c multi-path sweep.
struct PathRow {
    paths: usize,
    capped: bool,
    epoch_secs: f64,
    throughput_mb_s: f64,
    loss_bits: Vec<u32>,
}

/// Per-path line rate of the multi-path sweep (bytes/sec).  BASELINE
/// raw-image streaming at this rate is wire-bound on the sim profiles,
/// so achieved read throughput tracks the aggregate path capacity.
const PER_PATH_RATE: u64 = 2_000_000;

fn run_paths(paths: usize, aggregate_cap: Option<u64>) -> PathRow {
    let mut cfg = HapiConfig::sim();
    cfg.net_paths = paths;
    cfg.bandwidth = Some(PER_PATH_RATE); // equal rate *per path*
    cfg.aggregate_bandwidth = aggregate_cap;
    cfg.pipeline_depth = 2; // keep every path's bucket draining
    cfg.train_batch = 100; // 5 shards per iteration
    let bed = Testbed::launch(cfg).expect("launch");
    // BASELINE streams raw images (split 0): the heaviest read
    // workload, so the wire — not compute — is the bottleneck, and
    // the ~3 MB epoch dwarfs the buckets' burst credit.
    let (ds, labels) =
        bed.dataset("f16c", "simnet", 4000).expect("dataset");
    let client = bed
        .baseline_client("simnet", DeviceKind::Gpu)
        .expect("client");
    let t0 = std::time::Instant::now();
    let stats = client.train_epoch(&ds, &labels).expect("epoch");
    let epoch_secs = t0.elapsed().as_secs_f64();
    assert!(stats.max_inflight <= 2, "backpressure violated");
    bed.stop();
    PathRow {
        paths,
        capped: aggregate_cap.is_some(),
        epoch_secs,
        throughput_mb_s: stats.bytes_from_cos as f64 / epoch_secs / 1e6,
        loss_bits: stats.loss.iter().map(|l| l.to_bits()).collect(),
    }
}

fn multipath_section(report: &mut BenchReport) {
    println!("\n== Fig 16c: multi-path aggregate-bandwidth sweep ==\n");
    let mut rows: Vec<PathRow> =
        [1usize, 2, 4].iter().map(|&p| run_paths(p, None)).collect();
    // 2 paths under a 1×-path NIC cap: fanout alone cannot beat the
    // aggregate bucket.
    rows.push(run_paths(2, Some(PER_PATH_RATE)));

    let mut t = Table::new(
        "BASELINE, simnet, depth 2, 2 MB/s per path",
        &["paths", "NIC cap", "epoch (s)", "read throughput (MB/s)"],
    );
    for r in &rows {
        t.row(vec![
            r.paths.to_string(),
            if r.capped { "1 path-rate" } else { "none" }.to_string(),
            format!("{:.2}", r.epoch_secs),
            format!("{:.2}", r.throughput_mb_s),
        ]);
    }
    t.print();

    let (one, two, four, capped) =
        (&rows[0], &rows[1], &rows[2], &rows[3]);
    for r in &rows {
        let tag = if r.capped {
            format!("fig16c.paths{}_capped", r.paths)
        } else {
            format!("fig16c.paths{}", r.paths)
        };
        report.value(&format!("{tag}.epoch_secs"), r.epoch_secs);
        report
            .value(&format!("{tag}.throughput_mb_s"), r.throughput_mb_s);
    }
    // Loss trajectories are bitwise identical however many paths (and
    // whatever cap) carried the bytes.
    for r in &rows[1..] {
        assert_eq!(
            r.loss_bits, one.loss_bits,
            "path layout changed the loss trajectory"
        );
    }
    // Aggregate throughput scales ~linearly with the path count…
    let ratio2 = two.throughput_mb_s / one.throughput_mb_s;
    let ratio4 = four.throughput_mb_s / one.throughput_mb_s;
    println!(
        "\nthroughput scaling vs 1 path: 2 paths {ratio2:.2}x, \
         4 paths {ratio4:.2}x"
    );
    assert!(
        ratio2 >= 1.8,
        "2 paths must scale aggregate throughput >= 1.8x (got {ratio2:.2}x)"
    );
    assert!(
        ratio4 > ratio2,
        "4 paths must out-scale 2 ({ratio4:.2}x vs {ratio2:.2}x)"
    );
    // …until the client-NIC aggregate cap binds.
    let ratio_capped = capped.throughput_mb_s / one.throughput_mb_s;
    println!("2 paths under 1-path NIC cap: {ratio_capped:.2}x");
    assert!(
        ratio_capped <= 1.3,
        "NIC cap failed to bind: {ratio_capped:.2}x"
    );
    println!(
        "\nPASS: aggregate throughput scales with path count until \
         the NIC cap binds; loss bitwise stable"
    );
}

/// One run of the §fig16d degraded-path experiment.
struct DegRow {
    label: &'static str,
    epoch_secs: f64,
    throughput_mb_s: f64,
    path_bytes: [u64; 2],
    repins: u64,
    hedges: u64,
    hedge_bytes: u64,
    loss_bits: Vec<u32>,
}

/// Hard cap on duplicated bytes for the §fig16d scheduler run.
const HEDGE_CAP: u64 = 512 * 1024;

/// Run one BASELINE epoch over a 2-path/NIC-capped topology.  With
/// `degrade`, path 0 drops to 25% of its rate ~300 ms in (mid-run);
/// with `repin`, the goodput-aware scheduler may migrate slots and
/// hedge stragglers.
fn run_degraded(
    label: &'static str,
    degrade: bool,
    repin: bool,
) -> DegRow {
    let mut cfg = HapiConfig::sim();
    cfg.net_paths = 2;
    cfg.bandwidth = Some(PER_PATH_RATE);
    // A client-NIC cap keeps the healthy baseline honest: 2 paths
    // cannot outrun the NIC, so the recovery target is bounded.
    cfg.aggregate_bandwidth = Some(PER_PATH_RATE * 5 / 4);
    // Two 100-sample shards (~77 KB raw each, bigger than any bucket
    // burst, so a degraded path is visible per fetch) per iteration
    // over two slots at depth 1: every iteration fetches exactly one
    // shard on each path, so under static pinning every iteration
    // waits on the slow front end — the engine cannot rebalance by
    // claim order, and only the pinning policy decides throughput.
    cfg.pipeline_depth = 1;
    cfg.fetch_fanout = 2;
    cfg.object_samples = 100;
    cfg.train_batch = 200;
    cfg.client_id = 2; // even id: slot i → path i
    if repin {
        cfg.repin_threshold_pct = 70;
        cfg.repin_interval_ms = 50;
        cfg.hedge_factor_pct = 50;
        cfg.hedge_max_bytes = HEDGE_CAP;
    }
    let bed = Testbed::launch(cfg).expect("launch");
    let (ds, labels) =
        bed.dataset("f16d", "simnet", 4000).expect("dataset");
    let client = bed
        .baseline_client("simnet", DeviceKind::Gpu)
        .expect("client");
    let killer = degrade.then(|| {
        let net = bed.net.clone();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(300));
            net.set_path_rate(0, PER_PATH_RATE / 4);
        })
    });
    let t0 = std::time::Instant::now();
    let stats = client.train_epoch(&ds, &labels).expect("epoch");
    let epoch_secs = t0.elapsed().as_secs_f64();
    if let Some(k) = killer {
        k.join().unwrap();
    }
    let row = DegRow {
        label,
        epoch_secs,
        throughput_mb_s: stats.bytes_from_cos as f64 / epoch_secs / 1e6,
        path_bytes: [
            bed.registry.counter(&names::path_bytes(0)).get(),
            bed.registry.counter(&names::path_bytes(1)).get(),
        ],
        repins: bed.registry.counter(names::PIPELINE_REPINS).get(),
        hedges: bed.registry.counter(names::PIPELINE_HEDGES).get(),
        hedge_bytes: bed.registry.counter(names::PIPELINE_HEDGE_BYTES).get(),
        loss_bits: stats.loss.iter().map(|l| l.to_bits()).collect(),
    };
    bed.stop();
    row
}

fn repin_section(report: &mut BenchReport) {
    println!(
        "\n== Fig 16d: degraded-path recovery, re-pinning on vs off ==\n"
    );
    let healthy = run_degraded("healthy", false, false);
    let fixed = run_degraded("static pinning", true, false);
    let moved = run_degraded("goodput re-pinning", true, true);
    let rows = [&healthy, &fixed, &moved];

    let mut t = Table::new(
        "BASELINE, simnet, 2 paths @ 2 MB/s under a 2.5 MB/s NIC cap, \
         path 0 → 25% rate at t=300 ms",
        &[
            "policy",
            "epoch (s)",
            "throughput (MB/s)",
            "path bytes (0 / 1)",
            "repins",
            "hedges",
        ],
    );
    for r in rows {
        t.row(vec![
            r.label.to_string(),
            format!("{:.2}", r.epoch_secs),
            format!("{:.2}", r.throughput_mb_s),
            format!("{} / {}", r.path_bytes[0], r.path_bytes[1]),
            r.repins.to_string(),
            r.hedges.to_string(),
        ]);
    }
    t.print();

    // The trajectory is bitwise identical however the bytes were
    // routed — degradation, migration and hedging change timing only.
    for r in &rows[1..] {
        assert_eq!(
            r.loss_bits, healthy.loss_bits,
            "{}: transport policy changed the loss trajectory",
            r.label
        );
    }
    // Static pinning kept feeding the slow path; the scheduler
    // migrated off it (pre-migration samples aside).
    assert_eq!(fixed.repins, 0);
    assert!(moved.repins >= 1, "no slot migrated off the slow path");
    assert!(
        moved.path_bytes[1] > fixed.path_bytes[1],
        "migration must shift bytes to the healthy path"
    );
    // Duplicated bytes respect the hard cap.
    assert!(
        moved.hedge_bytes <= HEDGE_CAP,
        "hedged bytes {} exceed the {HEDGE_CAP}-byte cap",
        moved.hedge_bytes
    );
    // The headline: re-pinning recovers ≥ 30% of the throughput the
    // degradation cost under static pinning.
    let lost = healthy.throughput_mb_s - fixed.throughput_mb_s;
    let recovered = moved.throughput_mb_s - fixed.throughput_mb_s;
    let frac = recovered / lost.max(1e-9);
    for (slug, r) in
        [("healthy", &healthy), ("static", &fixed), ("repin", &moved)]
    {
        report.value(&format!("fig16d.{slug}.epoch_secs"), r.epoch_secs);
        report.value(
            &format!("fig16d.{slug}.throughput_mb_s"),
            r.throughput_mb_s,
        );
        report.value(&format!("fig16d.{slug}.repins"), r.repins as f64);
        report.value(&format!("fig16d.{slug}.hedges"), r.hedges as f64);
        report.value(
            &format!("fig16d.{slug}.hedge_bytes"),
            r.hedge_bytes as f64,
        );
    }
    report.value("fig16d.recovered_frac", frac);
    println!(
        "\nthroughput: healthy {:.2}, static {:.2}, re-pinned {:.2} \
         MB/s -> recovered {:.0}% of the degradation loss \
         (hedged {} B of {} B cap)",
        healthy.throughput_mb_s,
        fixed.throughput_mb_s,
        moved.throughput_mb_s,
        frac * 100.0,
        moved.hedge_bytes,
        HEDGE_CAP,
    );
    assert!(
        lost > 0.0,
        "degradation did not hurt static pinning — experiment broken"
    );
    assert!(
        frac >= 0.30,
        "re-pinning recovered only {:.0}% (< 30%) of the lost \
         throughput",
        frac * 100.0
    );
    println!(
        "\nPASS: re-pinning recovers >= 30% of the degradation loss; \
         hedged bytes capped; loss bitwise stable"
    );
}

fn main() {
    let args = Args::from_env().expect("args");
    let mut report = BenchReport::new("fig16_fetch_fanout");
    println!("== Fig 16b: fetch-fanout sweep (sim backend) ==\n");
    let rows: Vec<Row> =
        [1usize, 2, 4].iter().map(|&f| run_fanout(f)).collect();
    for r in &rows {
        let tag = format!("fig16b.fanout{}", r.fanout);
        report.value(&format!("{tag}.epoch_secs"), r.epoch_secs);
        report.value(
            &format!("{tag}.stall_ms_per_iter"),
            r.stall_ms_per_iter,
        );
    }

    let mut t = Table::new(
        "Hapi, simnet, depth 1, 5 shards/iter, shaped 4 MB/s link",
        &["fanout", "epoch (s)", "stall/iter (ms)", "max in-flight"],
    );
    for r in &rows {
        t.row(vec![
            r.fanout.to_string(),
            format!("{:.2}", r.epoch_secs),
            format!("{:.1}", r.stall_ms_per_iter),
            r.inflight_max.to_string(),
        ]);
    }
    t.print();

    let f1 = &rows[0];
    let f2 = &rows[1];
    println!(
        "\nfanout 2 vs 1: stall {:.1} -> {:.1} ms/iter ({:.0}% less), \
         epoch {:.2} -> {:.2} s",
        f1.stall_ms_per_iter,
        f2.stall_ms_per_iter,
        100.0 * (1.0 - f2.stall_ms_per_iter / f1.stall_ms_per_iter.max(1e-9)),
        f1.epoch_secs,
        f2.epoch_secs,
    );
    for r in &rows {
        assert!(
            r.inflight_max <= 1,
            "backpressure violated at fanout {}",
            r.fanout
        );
    }
    assert!(
        f2.stall_ms_per_iter < f1.stall_ms_per_iter,
        "fanout 2 must strictly reduce per-iteration stall \
         ({:.2} ms vs {:.2} ms)",
        f2.stall_ms_per_iter,
        f1.stall_ms_per_iter
    );
    println!("PASS: fanout >= 2 strictly reduces per-iteration stall");

    multipath_section(&mut report);
    repin_section(&mut report);

    if let Some(path) = json_path(&args) {
        report.write(&path).expect("write bench report");
        println!("\nwrote {path}");
    }
}
