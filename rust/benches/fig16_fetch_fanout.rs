//! Fig 16 (extension) — fetch-fanout sweep: per-iteration network stall
//! vs `fetch_fanout`, Hapi on the SimBackend under a bandwidth-shaped
//! link with modeled COS compute.
//!
//! This is the sharded-fetch axis of the prefetch engine (the
//! depth-sweep sibling is `fig16_pipeline_depth`): with several shards
//! per iteration, fanout 1 drains every POST over a single COS
//! connection — each shard's server-side feature extraction and
//! round-trip serialise behind the previous one.  Fanout ≥ 2 fans the
//! shards over parallel connections so their COS compute and latency
//! overlap; only the wire bytes still serialise on the shaped link.
//! Expected shape: fanout ≥ 2 strictly reduces per-iteration stall vs
//! fanout 1, with diminishing returns once the pool covers the
//! shards-per-iteration.
//!
//! §fig16c adds the multi-path axis: with per-path token buckets
//! (`net_paths`, one proxy front end per path) the same fanout stops
//! being a latency tool and becomes aggregate-bandwidth scaling —
//! throughput grows ~linearly in the path count at equal per-path rate,
//! until the client-NIC aggregate cap binds; the learning trajectory
//! stays bitwise identical throughout.
//!
//! Artifact-free by construction (SimBackend): runs on a fresh clone.

use hapi::config::HapiConfig;
use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::runtime::DeviceKind;

struct Row {
    fanout: usize,
    epoch_secs: f64,
    stall_ms_per_iter: f64,
    inflight_max: usize,
}

fn run_fanout(fanout: usize) -> Row {
    let mut cfg = HapiConfig::sim();
    cfg.pipeline_depth = 1;
    cfg.fetch_fanout = fanout;
    // 5 shards per iteration (train batch 100 over 20-sample objects);
    // ~17 ms of modeled COS feature extraction per POST dominates the
    // per-shard cost, so serialising the 5 POSTs (fanout 1) leaves the
    // trainer stalled for most of the fetch.
    cfg.sim_compute_gflops = 5.0;
    cfg.bandwidth = Some(4_000_000); // bytes/sec: a 32 Mbps link
    cfg.train_batch = 100;
    let bed = Testbed::launch(cfg).expect("launch");
    let (ds, labels) = bed.dataset("f16f", "simnet", 1000).expect("dataset");
    let client = bed
        .hapi_client("simnet", DeviceKind::Gpu)
        .expect("client");
    let t0 = std::time::Instant::now();
    let stats = client.train_epoch(&ds, &labels).expect("epoch");
    let epoch_secs = t0.elapsed().as_secs_f64();
    bed.stop();
    Row {
        fanout,
        epoch_secs,
        stall_ms_per_iter: stats.comm.as_secs_f64() * 1e3
            / stats.iterations as f64,
        inflight_max: stats.max_inflight,
    }
}

/// One row of the §fig16c multi-path sweep.
struct PathRow {
    paths: usize,
    capped: bool,
    epoch_secs: f64,
    throughput_mb_s: f64,
    loss_bits: Vec<u32>,
}

/// Per-path line rate of the multi-path sweep (bytes/sec).  BASELINE
/// raw-image streaming at this rate is wire-bound on the sim profiles,
/// so achieved read throughput tracks the aggregate path capacity.
const PER_PATH_RATE: u64 = 2_000_000;

fn run_paths(paths: usize, aggregate_cap: Option<u64>) -> PathRow {
    let mut cfg = HapiConfig::sim();
    cfg.net_paths = paths;
    cfg.bandwidth = Some(PER_PATH_RATE); // equal rate *per path*
    cfg.aggregate_bandwidth = aggregate_cap;
    cfg.pipeline_depth = 2; // keep every path's bucket draining
    cfg.train_batch = 100; // 5 shards per iteration
    let bed = Testbed::launch(cfg).expect("launch");
    // BASELINE streams raw images (split 0): the heaviest read
    // workload, so the wire — not compute — is the bottleneck, and
    // the ~3 MB epoch dwarfs the buckets' burst credit.
    let (ds, labels) =
        bed.dataset("f16c", "simnet", 4000).expect("dataset");
    let client = bed
        .baseline_client("simnet", DeviceKind::Gpu)
        .expect("client");
    let t0 = std::time::Instant::now();
    let stats = client.train_epoch(&ds, &labels).expect("epoch");
    let epoch_secs = t0.elapsed().as_secs_f64();
    assert!(stats.max_inflight <= 2, "backpressure violated");
    bed.stop();
    PathRow {
        paths,
        capped: aggregate_cap.is_some(),
        epoch_secs,
        throughput_mb_s: stats.bytes_from_cos as f64 / epoch_secs / 1e6,
        loss_bits: stats.loss.iter().map(|l| l.to_bits()).collect(),
    }
}

fn multipath_section() {
    println!("\n== Fig 16c: multi-path aggregate-bandwidth sweep ==\n");
    let mut rows: Vec<PathRow> =
        [1usize, 2, 4].iter().map(|&p| run_paths(p, None)).collect();
    // 2 paths under a 1×-path NIC cap: fanout alone cannot beat the
    // aggregate bucket.
    rows.push(run_paths(2, Some(PER_PATH_RATE)));

    let mut t = Table::new(
        "BASELINE, simnet, depth 2, 2 MB/s per path",
        &["paths", "NIC cap", "epoch (s)", "read throughput (MB/s)"],
    );
    for r in &rows {
        t.row(vec![
            r.paths.to_string(),
            if r.capped { "1 path-rate" } else { "none" }.to_string(),
            format!("{:.2}", r.epoch_secs),
            format!("{:.2}", r.throughput_mb_s),
        ]);
    }
    t.print();

    let (one, two, four, capped) =
        (&rows[0], &rows[1], &rows[2], &rows[3]);
    // Loss trajectories are bitwise identical however many paths (and
    // whatever cap) carried the bytes.
    for r in &rows[1..] {
        assert_eq!(
            r.loss_bits, one.loss_bits,
            "path layout changed the loss trajectory"
        );
    }
    // Aggregate throughput scales ~linearly with the path count…
    let ratio2 = two.throughput_mb_s / one.throughput_mb_s;
    let ratio4 = four.throughput_mb_s / one.throughput_mb_s;
    println!(
        "\nthroughput scaling vs 1 path: 2 paths {ratio2:.2}x, \
         4 paths {ratio4:.2}x"
    );
    assert!(
        ratio2 >= 1.8,
        "2 paths must scale aggregate throughput >= 1.8x (got {ratio2:.2}x)"
    );
    assert!(
        ratio4 > ratio2,
        "4 paths must out-scale 2 ({ratio4:.2}x vs {ratio2:.2}x)"
    );
    // …until the client-NIC aggregate cap binds.
    let ratio_capped = capped.throughput_mb_s / one.throughput_mb_s;
    println!("2 paths under 1-path NIC cap: {ratio_capped:.2}x");
    assert!(
        ratio_capped <= 1.3,
        "NIC cap failed to bind: {ratio_capped:.2}x"
    );
    println!(
        "\nPASS: aggregate throughput scales with path count until \
         the NIC cap binds; loss bitwise stable"
    );
}

fn main() {
    println!("== Fig 16b: fetch-fanout sweep (sim backend) ==\n");
    let rows: Vec<Row> =
        [1usize, 2, 4].iter().map(|&f| run_fanout(f)).collect();

    let mut t = Table::new(
        "Hapi, simnet, depth 1, 5 shards/iter, shaped 4 MB/s link",
        &["fanout", "epoch (s)", "stall/iter (ms)", "max in-flight"],
    );
    for r in &rows {
        t.row(vec![
            r.fanout.to_string(),
            format!("{:.2}", r.epoch_secs),
            format!("{:.1}", r.stall_ms_per_iter),
            r.inflight_max.to_string(),
        ]);
    }
    t.print();

    let f1 = &rows[0];
    let f2 = &rows[1];
    println!(
        "\nfanout 2 vs 1: stall {:.1} -> {:.1} ms/iter ({:.0}% less), \
         epoch {:.2} -> {:.2} s",
        f1.stall_ms_per_iter,
        f2.stall_ms_per_iter,
        100.0 * (1.0 - f2.stall_ms_per_iter / f1.stall_ms_per_iter.max(1e-9)),
        f1.epoch_secs,
        f2.epoch_secs,
    );
    for r in &rows {
        assert!(
            r.inflight_max <= 1,
            "backpressure violated at fanout {}",
            r.fanout
        );
    }
    assert!(
        f2.stall_ms_per_iter < f1.stall_ms_per_iter,
        "fanout 2 must strictly reduce per-iteration stall \
         ({:.2} ms vs {:.2} ms)",
        f2.stall_ms_per_iter,
        f1.stall_ms_per_iter
    );
    println!("PASS: fanout >= 2 strictly reduces per-iteration stall");

    multipath_section();
}
