//! Fig 14 + Table 5 — the benefits of batch adaptation.
//!
//! The COS batch is forced to the whole object (100, paper: 1000) and
//! the training batch sweeps 100..800 (paper: 1000..8000), i.e. 1..8
//! parallel POSTs.  Without BA the device ledger overflows beyond ~6
//! concurrent requests (X); with BA the planner reduces COS batches and
//! every epoch completes.  Table 5's adaptation stats come from the
//! planner's counters.

#[path = "common.rs"]
mod common;

use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::runtime::DeviceKind;
use hapi::util::fmt_bytes;

fn main() {
    println!("== Fig 14 / Table 5: batch adaptation ==\n");
    let mut t = Table::new(
        "alexnet, COS batch forced to the full object (100)",
        &[
            "train batch",
            "posts",
            "no-BA time (s)",
            "no-BA status",
            "BA time (s)",
            "BA peak mem",
            "% reduced",
            "avg reduction %",
            "p95 reduction %",
        ],
    );
    let mut no_ba_oom_at = None;
    for paper_batch in [1000usize, 2000, 4000, 6000, 7000, 8000] {
        let batch = common::scaled(paper_batch);
        let posts = batch / 100;
        let mut row = vec![batch.to_string(), posts.to_string()];
        let mut ba_stats = (0u64, 0u64, 0.0f64);
        let mut ba_p95 = 0.0f64;
        let mut ba_peak = 0u64;
        for ba in [false, true] {
            let mut cfg = common::bench_config();
            cfg.bandwidth = None;
            cfg.train_batch = batch;
            cfg.default_cos_batch = 100; // forced: the no-BA overload knob
            cfg.batch_adaptation = ba;
            let bed = Testbed::launch(cfg).unwrap();
            let (ds, labels) = bed.dataset("f14", "alexnet", batch).unwrap();
            bed.server.warm("alexnet").unwrap();
            let client =
                bed.hapi_client("alexnet", DeviceKind::Gpu).unwrap();
            let t0 = std::time::Instant::now();
            let out = client.train_epoch(&ds, &labels);
            let secs = t0.elapsed().as_secs_f64();
            if !ba {
                match out {
                    Ok(_) => {
                        row.push(format!("{secs:.1}"));
                        row.push("ok".into());
                    }
                    Err(e) if e.is_oom() => {
                        row.push("-".into());
                        row.push("X (OOM)".into());
                        no_ba_oom_at.get_or_insert(batch);
                    }
                    Err(e) => panic!("unexpected: {e}"),
                }
            } else {
                assert!(out.is_ok(), "BA epoch failed: {out:?}");
                row.push(format!("{secs:.1}"));
                ba_stats = bed.server.planner().adaptation_stats();
                ba_p95 =
                    bed.server.planner().reduction_pct_quantile(0.95);
                ba_peak = bed
                    .server
                    .devices()
                    .iter()
                    .map(|d| d.peak_with_reserved())
                    .max()
                    .unwrap();
            }
            bed.stop();
        }
        let (total, reduced, avg_pct) = ba_stats;
        row.push(fmt_bytes(ba_peak));
        row.push(format!(
            "{:.1}",
            100.0 * reduced as f64 / total.max(1) as f64
        ));
        row.push(format!("{avg_pct:.1}"));
        row.push(format!("{ba_p95:.1}"));
        t.row(row);
    }
    t.print();
    println!(
        "\npaper shape: no-BA crashes beyond ~6 concurrent requests \
         (ours first OOM at train batch {:?}); BA levels memory and \
         completes everything (Table 5: reductions appear from 6000 up)",
        no_ba_oom_at
    );
    assert!(no_ba_oom_at.is_some(), "no-BA should OOM at some batch");
}
