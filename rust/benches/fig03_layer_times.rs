//! Fig 3 — per-layer forward computation time, CPU vs GPU.
//!
//! Real execution per unit (PJRT CPU) gives the GPU-tier line (native);
//! the CPU-tier line applies the per-kind device model (DESIGN.md §2).
//! Expected shape: early conv units dominate; the epilogue units cost
//! nearly the same on both tiers (the weak-client enabler).

#[path = "common.rs"]
mod common;

use std::sync::Arc;
use std::time::Duration;

use hapi::metrics::table::fnum;
use hapi::metrics::Table;
use hapi::model::ModelRegistry;
use hapi::runtime::{DeviceKind, Engine, ModelArtifacts, Tensor};
use hapi::util::rng::Rng;

fn main() {
    let cfg = common::bench_config();
    let engine = Engine::cpu().unwrap();
    let reg = ModelRegistry::load_dir(cfg.profiles_dir()).unwrap();
    let batch = common::scaled(200);

    println!("== Fig 3: per-unit forward time at batch {batch} ==\n");
    for name in common::STUDY_MODELS {
        let profile = reg.get(name).unwrap();
        let arts = Arc::new(
            ModelArtifacts::load(
                engine.clone(),
                profile.clone(),
                cfg.model_dir(name),
            )
            .unwrap(),
        );
        let mut rng = Rng::new(7);
        let elems: usize =
            profile.tiny.input_shape.iter().product::<usize>() * batch;
        let data: Vec<f32> = (0..elems).map(|_| rng.normal()).collect();
        let mut dims = vec![batch];
        dims.extend(&profile.tiny.input_shape);
        let x = Tensor::from_f32(dims, &data);

        // Warm (compile) then measure.
        arts.warm().unwrap();
        let mut times: Vec<Duration> = Vec::new();
        arts.forward_segment(
            &x,
            1,
            profile.num_units,
            DeviceKind::Gpu,
            Some(&mut times),
        )
        .unwrap();

        let mut t = Table::new(
            &format!("{name}"),
            &["unit", "name", "kind", "GPU ms", "CPU ms (modeled)"],
        );
        for i in 1..=profile.num_units {
            let u = &profile.tiny.units[i - 1];
            let gpu_ms = times[i].as_secs_f64() * 1e3;
            let cpu_ms = gpu_ms * DeviceKind::Cpu.slowdown(u.kind);
            t.row(vec![
                i.to_string(),
                u.name.clone(),
                format!("{:?}", u.kind),
                fnum(gpu_ms),
                fnum(cpu_ms),
            ]);
        }
        t.print();

        // Shape checks: conv-ish prefix dominates; epilogue CPU≈GPU.
        let dense_prefix: f64 = (1..=profile.freeze_idx.min(8))
            .map(|i| times[i].as_secs_f64())
            .sum();
        let total: f64 =
            (1..=profile.num_units).map(|i| times[i].as_secs_f64()).sum();
        println!(
            "first-8-unit share of total: {:.0}%\n",
            100.0 * dense_prefix / total
        );
    }
}
