//! Fig 15 — total device memory used by Hapi (client + COS) vs the
//! BASELINE (client only), at two COS batch sizes.
//!
//! Expected shape: with a large COS batch the aggregate exceeds what the
//! client alone could provide (the "extra memory" illusion); with a
//! small COS batch the aggregate drops below the BASELINE — the COS
//! batch knob controls memory.

#[path = "common.rs"]
mod common;

use hapi::config::Scale;
use hapi::metrics::Table;
use hapi::model::ModelRegistry;
use hapi::netsim;
use hapi::profiler::AppProfile;
use hapi::split::choose_split_idx;
use hapi::util::fmt_bytes;

fn main() {
    let cfg = common::bench_config();
    let reg = ModelRegistry::load_dir(cfg.profiles_dir()).unwrap();
    let app = AppProfile::new(reg.get("alexnet").unwrap(), Scale::Tiny);
    let mem = app.memory();
    let client_cap = cfg.client_gpu_mem;

    println!("== Fig 15: memory breakdown, Hapi vs BASELINE (alexnet) ==\n");
    for cos_batch in [100usize, 20] {
        let mut t = Table::new(
            &format!("COS batch {cos_batch}"),
            &[
                "train batch",
                "posts",
                "client mem",
                "COS mem (all posts)",
                "Hapi total",
                "BASELINE client",
                "BASE > client cap?",
            ],
        );
        for paper_batch in [2000usize, 4000, 8000, 12000] {
            let batch = common::scaled(paper_batch);
            let posts = batch / 100;
            let split = choose_split_idx(
                &app,
                Some(netsim::mbps(100.0)),
                1.0,
                batch,
            )
            .split_idx;
            let client = mem.client_bytes(split, batch);
            let cos =
                posts as u64 * mem.fe_request_bytes(split, cos_batch.min(100));
            let base = mem.baseline_client_bytes(batch);
            t.row(vec![
                batch.to_string(),
                posts.to_string(),
                fmt_bytes(client),
                fmt_bytes(cos),
                fmt_bytes(client + cos),
                fmt_bytes(base),
                if base > client_cap { "X (OOM)" } else { "" }.into(),
            ]);
        }
        t.print();
        println!();
    }

    // Shape assertions: the aggregate at the big COS batch and train
    // batch 1200 exceeds the client capability (the paper's ">30 GB at
    // batch 12000" point), while the small COS batch drops aggregate
    // usage below the BASELINE.
    let split = choose_split_idx(&app, Some(netsim::mbps(100.0)), 1.0, 1200)
        .split_idx;
    let big = mem.client_bytes(split, 1200)
        + 12 * mem.fe_request_bytes(split, 100);
    assert!(
        big > client_cap,
        "aggregate ({}) should exceed the client capability ({})",
        fmt_bytes(big),
        fmt_bytes(client_cap)
    );
    let small = mem.client_bytes(split, 400)
        + 4 * mem.fe_request_bytes(split, 20);
    assert!(
        small < mem.baseline_client_bytes(400),
        "small COS batch should undercut the BASELINE"
    );
    println!("shape checks passed");
}
