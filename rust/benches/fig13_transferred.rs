//! Fig 13 — average data transferred per training iteration vs the
//! training batch size.
//!
//! Expected shape: BASELINE grows linearly with the batch; Hapi stays
//! nearly constant (upper-bounded) because Algorithm 1 moves the split
//! index later as the batch grows.

#[path = "common.rs"]
mod common;

use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::runtime::DeviceKind;
use hapi::util::fmt_bytes;

fn main() {
    println!("== Fig 13: bytes per iteration vs training batch ==\n");
    let mut t = Table::new(
        "alexnet, 2 Mbps link",
        &["train batch", "Hapi split", "Hapi bytes/iter", "BASE bytes/iter"],
    );
    let mut hapi_bytes = Vec::new();
    let mut base_bytes = Vec::new();
    for paper_batch in [1000usize, 2000, 4000, 6000, 8000] {
        let batch = common::scaled(paper_batch);
        let mut cfg = common::bench_config();
        cfg.bandwidth = Some(hapi::netsim::mbps(2.0));
        cfg.train_batch = batch;
        let bed = Testbed::launch(cfg).unwrap();
        let (ds, labels) = bed.dataset("f13", "alexnet", batch).unwrap();
        bed.server.warm("alexnet").unwrap();

        let hapi = bed.hapi_client("alexnet", DeviceKind::Gpu).unwrap();
        let hs = hapi.train_epoch(&ds, &labels).unwrap();
        let hb = hs.bytes_from_cos / hs.iterations.max(1) as u64;

        let base = bed.baseline_client("alexnet", DeviceKind::Gpu).unwrap();
        let bs = base.train_epoch(&ds, &labels).unwrap();
        let bb = bs.bytes_from_cos / bs.iterations.max(1) as u64;

        t.row(vec![
            batch.to_string(),
            hapi.split.split_idx.to_string(),
            fmt_bytes(hb),
            fmt_bytes(bb),
        ]);
        hapi_bytes.push(hb as f64);
        base_bytes.push(bb as f64);
        bed.stop();
    }
    t.print();

    let base_growth = base_bytes.last().unwrap() / base_bytes[0];
    let reduction = base_bytes.last().unwrap() / hapi_bytes.last().unwrap();
    println!(
        "\n8x batch growth -> BASELINE bytes x{base_growth:.1}; reduction \
         at the largest batch {reduction:.1}x (paper: BASELINE linear, \
         Hapi upper-bounded, up to 8.3x reduction)"
    );
    assert!(base_growth > 6.0, "BASELINE should grow ~linearly");
    // Hapi stays well below the BASELINE at every batch...
    for (h, b) in hapi_bytes.iter().zip(&base_bytes) {
        assert!(h * 4.0 < *b, "Hapi should transfer ≪ BASELINE");
    }
    // ...and shows the §7.6 signature: some batch *increase* shrinks the
    // bytes because the split moved later (the paper's 3000→4000 case).
    assert!(
        hapi_bytes.windows(2).any(|w| w[1] < w[0]),
        "expected a later-split byte drop somewhere in the sweep"
    );
}
