//! Fig 4 — per-layer maximum device memory (forward), plus the
//! aggregated backward-phase memory from the freeze index to the end.
//!
//! Expected shape: early units need the most memory; batch growth
//! inflates early units far faster; at large batches the early units
//! exceed the whole backward phase — the motivation for COS-side batch
//! adaptation.

#[path = "common.rs"]
mod common;

use hapi::config::Scale;
use hapi::metrics::Table;
use hapi::model::ModelRegistry;
use hapi::profiler::AppProfile;
use hapi::util::fmt_bytes;

fn main() {
    let cfg = common::bench_config();
    let reg = ModelRegistry::load_dir(cfg.profiles_dir()).unwrap();
    let batches = [common::scaled(200), common::scaled(500), common::scaled(1000)];

    println!("== Fig 4: per-unit forward memory + backward aggregate ==\n");
    for name in common::STUDY_MODELS {
        let app = AppProfile::new(reg.get(name).unwrap(), Scale::Tiny);
        let mem = app.memory();
        let mut t = Table::new(
            &format!("{name} (freeze {})", app.freeze_idx()),
            &[
                "unit",
                &format!("fwd b={}", batches[0]),
                &format!("fwd b={}", batches[1]),
                &format!("fwd b={}", batches[2]),
            ],
        );
        for i in 1..=app.num_units() {
            t.row(vec![
                format!("{i} {}", app.meta().units[i - 1].name),
                fmt_bytes(mem.unit_forward_bytes(i, batches[0])),
                fmt_bytes(mem.unit_forward_bytes(i, batches[1])),
                fmt_bytes(mem.unit_forward_bytes(i, batches[2])),
            ]);
        }
        t.print();
        for &b in &batches {
            println!(
                "backward phase (units {}..{}) at b={b}: {}",
                app.freeze_idx() + 1,
                app.num_units(),
                fmt_bytes(mem.backward_bytes(b))
            );
        }

        // Shape assertions.
        let early_max = (1..=4)
            .map(|i| mem.unit_forward_bytes(i, batches[2]))
            .max()
            .unwrap();
        let late_max = (app.num_units() - 2..=app.num_units())
            .map(|i| mem.unit_forward_bytes(i, batches[2]))
            .max()
            .unwrap();
        assert!(
            early_max > late_max,
            "{name}: early units should dominate memory"
        );
        // Insight 3: at a large enough batch the early units out-weigh
        // the whole backward phase.
        assert!(
            early_max > mem.backward_bytes(batches[0]),
            "{name}: early fwd at b={} should exceed bwd at b={}",
            batches[2],
            batches[0]
        );
        println!();
    }
}
