//! §7.3 — dynamic splitting vs static splitting at the freeze layer.
//!
//! Paper setup: DenseNet121, 4 concurrent clients, unrestricted
//! bandwidth.  Hapi picks an *earlier* split (larger output, fewer
//! pushed-down units) and wins because COS time is multiplied by the
//! number of concurrent requests (Eq. 1's |R(t)|·L_COS term) while
//! client time is not (every tenant has its own compute tier).
//!
//! On this single-box testbed all four "clients" share the same CPU as
//! the COS, so the tier asymmetry the paper exploits cannot show up in
//! wall-clock — both strategies execute the same total work on one core.
//! The bench therefore (a) *measures* the per-unit costs and transfers
//! for both strategies on the real system, then (b) evaluates the §4
//! cost model with the measured constants under the paper's
//! dedicated-client assumption, which is where the 85.86 s vs 92.56 s
//! ordering must (and does) reappear.

#[path = "common.rs"]
mod common;

use std::sync::Arc;
use std::time::Duration;

use hapi::harness::Testbed;
use hapi::metrics::Table;
use hapi::runtime::{DeviceKind, ModelArtifacts, Tensor};
use hapi::split::choose_split_idx;
use hapi::theory::{predict, CostConstants};
use hapi::util::fmt_bytes;
use hapi::util::rng::Rng;

fn main() {
    println!("== §7.3: dynamic vs static-freeze split (densenet121, 4 clients) ==\n");
    let mut cfg = common::bench_config();
    cfg.bandwidth = None;
    cfg.train_batch = 100;
    let bed = Testbed::launch(cfg).unwrap();
    let profile = bed.models.get("densenet121").unwrap();
    let app = bed.app("densenet121").unwrap();
    let freeze = app.freeze_idx();
    let dynamic = choose_split_idx(&app, None, 1.0, 100).split_idx;
    assert!(dynamic < freeze, "dynamic should split earlier than freeze");

    // (a) Measure per-unit forward costs on the real runtime.
    let arts = Arc::new(
        ModelArtifacts::load(
            bed.engine.clone(),
            profile.clone(),
            bed.cfg.model_dir("densenet121"),
        )
        .unwrap(),
    );
    arts.warm().unwrap();
    let mut rng = Rng::new(5);
    let elems: usize = profile.tiny.input_shape.iter().product::<usize>() * 20;
    let vals: Vec<f32> = (0..elems).map(|_| rng.normal()).collect();
    let mut dims = vec![20usize];
    dims.extend(&profile.tiny.input_shape);
    let x = Tensor::from_f32(dims, &vals);
    let mut times: Vec<Duration> = Vec::new();
    arts.forward_segment(&x, 1, profile.num_units, DeviceKind::Gpu, Some(&mut times))
        .unwrap();
    let per_unit_secs: f64 = times.iter().map(|d| d.as_secs_f64()).sum::<f64>()
        / profile.num_units as f64;

    // (b) Fit the §4 constants from the measurement and predict under 4
    // concurrent tenants with dedicated client tiers.
    let k = CostConstants {
        c11: 1e-10,
        c12: per_unit_secs * 5.0, // per unit per request (batch 100)
        c21: 1e-10,
        c22: per_unit_secs * 5.0,
    };
    let p_dyn = predict(&app, &k, dynamic, 20, 100, 400, 4, 1e9);
    let p_static = predict(&app, &k, freeze, 20, 100, 400, 4, 1e9);

    // (c) Also run both strategies for real and report everything.
    let mut table = Table::new(
        "4 concurrent clients (measured + modelled)",
        &[
            "strategy",
            "split idx",
            "measured makespan",
            "bytes from COS",
            "modelled epoch (dedicated clients)",
        ],
    );
    for static_freeze in [false, true] {
        let (ds, labels) = bed.dataset("s73", "densenet121", 100).unwrap();
        bed.net.stats().reset();
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let c = if static_freeze {
                        bed.static_freeze_client("densenet121", DeviceKind::Gpu)
                    } else {
                        bed.hapi_client("densenet121", DeviceKind::Gpu)
                    }
                    .unwrap();
                    c.train_epoch(&ds, &labels).unwrap();
                });
            }
        });
        let makespan = t0.elapsed();
        let (split, modelled) = if static_freeze {
            (freeze, &p_static)
        } else {
            (dynamic, &p_dyn)
        };
        table.row(vec![
            if static_freeze { "static @ freeze" } else { "Hapi dynamic" }
                .into(),
            split.to_string(),
            format!("{:.1}s", makespan.as_secs_f64()),
            fmt_bytes(bed.net.stats().rx_bytes()),
            format!(
                "{:.1}s (COS {:.1} + client {:.1} + net {:.1})",
                modelled.total(),
                modelled.c_cos,
                modelled.c_client,
                modelled.t_data
            ),
        ]);
    }
    table.print();
    println!(
        "paper shape: the dynamic split transfers more yet wins once COS \
         time is shared 4 ways (85.86s vs 92.56s in the paper)."
    );
    assert!(
        p_dyn.total() < p_static.total(),
        "cost model must prefer the dynamic split under contention"
    );
    bed.stop();
}
